"""Spike-parcel transport benchmark: dense all-gather vs sparse parcels.

The optimized SPMD FAP round owns exactly two channels (stepping
notifications + spike parcels; ``repro.distributed.exchange``).  This
benchmark compiles the round for both transports on a forced-host-device
mesh and reports, per channel, the *measured* collective bytes from the
compiled HLO (``launch.hlo_analysis.collective_channel_bytes``) plus the
per-round wall time — across parcel caps sized for a low (quiet, 0.25 Hz)
and a high (burst, 55.8 Hz) firing regime.  The sparse transport's parcel
bytes must be identical across network sizes (they are a function of
n_shards * parcel_cap only) while the dense transport's grow with N; the
worker asserts this, so a transport regression fails the bench (and
``scripts/check.sh``, which runs it in quick mode).

A topology axis (``repro.core.topology``) covers the notify channel's
structural lever: on block-structured wiring the boundary frontier, and
hence notify bytes, must drop vs the uniform-random worst case by ~the
measured frontier ratio (asserted; see also ``benchmarks/placement.py``
for the placement-recovery grid on label-shuffled nets).

Runs in a subprocess (jax device counts lock at first init):
  quick (REPRO_BENCH_QUICK=1): 2x2 mesh,   N in {256, 1024},   soma model
  full:                        16x16 mesh, N in {64k, 1M},     soma model
Wall time is measured at N <= 64k only (the 1M cell is bytes-only — a 1M
neuron round on an emulated 256-device CPU mesh is compile-and-analyse
territory; an explicit "skipped" line records the omission).
"""
from __future__ import annotations

import os
import subprocess
import sys

REGIME_RATES = {"low": 0.25, "high": 55.8}     # Hz (quiet / burst, paper §4)
HORIZON_CAP = 2.0                              # ms advanced per round


def parcel_cap_for(rate_hz: float, n_local: int, k_in: int,
                   n_shards: int) -> int:
    """Static per-(src,dst) parcel cap for a firing regime: expected spikes
    per shard per round, fanned over destination shards, x4 headroom."""
    spikes = n_local * rate_hz * HORIZON_CAP * 1e-3
    per_dest = spikes * min(k_in, n_shards) / n_shards
    return max(4, int(4 * per_dest + 0.5))


def run() -> None:
    """Orchestrator entry (run.py / check.sh): spawn the forced-host-device
    worker, stream its CSV through, record it for the JSON dump."""
    from benchmarks.common import dump_json, record_csv

    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                        + ("4" if quick else "256"))
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.exchange", "--worker"],
        env=env, capture_output=True, text=True, cwd=root,
        timeout=(900 if quick else 7200))
    sys.stdout.write(res.stdout)
    record_csv(res.stdout)
    if res.returncode != 0:
        raise RuntimeError(f"exchange worker failed:\n{res.stderr[-3000:]}")
    dump_json("exchange")


def _worker() -> None:
    import jax
    import numpy as np

    jax.config.update("jax_enable_x64", True)

    from benchmarks.common import emit, timeit
    from repro.core import bdf, morphology, network
    from repro.core import exec_common as xc
    from repro.core.cell import CellModel
    from repro.distributed.exchange import ExchangeSpec
    from repro.distributed.fap_spmd import PaperNeuroSpec, build_fap_round
    from repro.launch.hlo_analysis import collective_channel_bytes
    from repro.launch.mesh import make_mesh_compat

    import jax.numpy as jnp

    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    shape = (2, 2) if quick else (16, 16)
    sizes = [256, 1024] if quick else [65536, 1 << 20]
    wall_max_n = 1024 if quick else 65536
    k_in = 4 if quick else 16
    mesh = make_mesh_compat(shape, ("data", "model"))
    n_shards = int(np.prod(shape))
    model = CellModel(morphology.soma_only())
    parcel = {}                    # (transport, regime, n) -> bytes
    notify = {}                    # (transport, regime, n) -> bytes

    def concrete_args(net, spec, targs):
        n = int(net.n)
        iinj = jnp.zeros((n,), jnp.float64)
        Y = xc.batch_init(model, n)
        sts = jax.vmap(lambda y, i: bdf.reinit(model, 0.0, y, i,
                                               bdf.BDFOptions()))(Y, iinj)
        dnet = xc.to_device(net)
        f8 = jnp.float64
        eq = (jnp.full((n, spec.ev_cap), jnp.inf, f8),
              jnp.zeros((n, spec.ev_cap), f8), jnp.zeros((n, spec.ev_cap), f8))
        return (sts, *eq, dnet.pre, dnet.post, dnet.delay, dnet.w_ampa,
                dnet.w_gaba, iinj) + targs

    for n in sizes:
        net = network.make_network(n, k_in=k_in, seed=0)
        n_local = n // n_shards
        cells = [("allgather", "any", 0)]
        for regime, rate in REGIME_RATES.items():
            cells.append(("sparse", regime,
                          parcel_cap_for(rate, n_local, k_in, n_shards)))
        for transport, regime, cap in cells:
            spec = PaperNeuroSpec(n_neurons=n, k_in=k_in, ev_cap=16,
                                  t_end=100.0)
            fn, args, sh = build_fap_round(
                model, spec, mesh, optimized=True, transport=transport,
                exchange=ExchangeSpec(parcel_cap=cap), net=net)
            compiled = jax.jit(fn, in_shardings=sh).lower(*args).compile()
            ch = collective_channel_bytes(compiled.as_text())
            parcel[(transport, regime, n)] = ch["exchange_parcel"]
            notify[(transport, regime, n)] = ch["exchange_notify"]
            tag = f"exchange/bytes/{transport}/{regime}/n{n}"
            emit(tag, 0.0,
                 f"parcel={ch['exchange_parcel']};"
                 f"notify={ch['exchange_notify']};other={ch['other']};"
                 f"cap={cap};n_shards={n_shards}")
            if n > wall_max_n:
                emit(f"exchange/round_wall/{transport}/{regime}/n{n}", 0.0,
                     "skipped=1M-round-on-emulated-mesh;bytes-only")
                continue
            cargs = jax.device_put(concrete_args(net, spec, args[10:]), sh)
            _, s = timeit(lambda: compiled(*cargs),
                          repeats=2 if quick else 3)
            emit(f"exchange/round_wall/{transport}/{regime}/n{n}", s * 1e6,
                 f"cap={cap}")

    # the activity-not-N contract, asserted (check.sh gate)
    n0, n1 = sizes
    for regime in REGIME_RATES:
        lo = parcel[("sparse", regime, n0)]
        hi = parcel[("sparse", regime, n1)]
        # caps may differ across N (they scale with n_local): compare
        # bytes *per cap slot*, which must be N-invariant
        cap0 = parcel_cap_for(REGIME_RATES[regime], n0 // n_shards, k_in,
                              n_shards)
        cap1 = parcel_cap_for(REGIME_RATES[regime], n1 // n_shards, k_in,
                              n_shards)
        ok = lo * cap1 == hi * cap0
        emit(f"exchange/scaling/{regime}", 0.0,
             f"sparse_bytes_per_slot_n_invariant={ok}")
        if not ok:
            raise AssertionError(
                f"sparse parcel bytes not cap-proportional: {lo}/{cap0} vs "
                f"{hi}/{cap1}")
    ag = [parcel[("allgather", "any", n)] for n in sizes]
    if not ag[1] > 2 * ag[0]:
        raise AssertionError(f"allgather parcel bytes did not grow with N: {ag}")
    emit("exchange/scaling/allgather", 0.0,
         f"bytes_grow_with_N={ag[1] > 2 * ag[0]}")

    # --- topology axis: block-structured wiring vs the uniform worst case --
    # The notify channel gathers the shard_frontier boundary set, so its
    # bytes must drop ~by the measured frontier ratio (the block locality
    # factor) on block wiring, while the uniform nets above stay ~N.
    from repro.core import topology
    from repro.distributed import placement as plc

    for n in sizes:
        net_b = network.make_network(
            n, k_in=k_in, seed=0,
            topology=topology.TopologyConfig("block", n_blocks=n_shards,
                                             p_in=0.99))
        cap = parcel_cap_for(REGIME_RATES["low"], n // n_shards, k_in,
                             n_shards)
        spec = PaperNeuroSpec(n_neurons=n, k_in=k_in, ev_cap=16, t_end=100.0)
        fn, args, sh = build_fap_round(
            model, spec, mesh, optimized=True, transport="sparse",
            exchange=ExchangeSpec(parcel_cap=cap), net=net_b)
        ch = collective_channel_bytes(
            jax.jit(fn, in_shardings=sh).lower(*args).compile().as_text())
        net_u = network.make_network(n, k_in=k_in, seed=0)
        f_u = plc.frontier_stats(net_u, n_shards)["F"]
        f_b = plc.frontier_stats(net_b, n_shards)["F"]
        base = notify[("sparse", "low", n)]
        b_ratio = base / max(1, ch["exchange_notify"])
        f_ratio = f_u / max(1, f_b)
        emit(f"exchange/bytes/sparse_block/n{n}", 0.0,
             f"notify={ch['exchange_notify']};parcel={ch['exchange_parcel']};"
             f"notify_uniform={base};byte_ratio={b_ratio:.2f};"
             f"frontier_ratio={f_ratio:.2f};F_uniform={f_u};F_block={f_b}")
        if not b_ratio >= max(2.0, 0.8 * f_ratio):
            raise AssertionError(
                f"block topology did not cut notify bytes by the locality "
                f"factor at n={n}: byte ratio {b_ratio:.2f} vs frontier "
                f"ratio {f_ratio:.2f}")

    # --- ragged transport axis (ISSUE 5): two-phase classed parcels -------
    # per-class payloads measured from the lowered module (each class
    # branch's sized all_to_all carries its own exchange_parcel_c<cap>
    # scope): every class but the last must sit strictly below the static
    # cap's bytes, and the last must equal them (ragged never ships more);
    # then a driven quiet-regime run must realize strictly fewer parcel
    # bytes than the static transport round-for-round.
    from repro.distributed.fap_spmd import run_fap_spmd

    from benchmarks.common import regime_iinj

    n_r = sizes[0]
    net_r = network.make_network(n_r, k_in=k_in, seed=0)
    cap = parcel_cap_for(REGIME_RATES["high"], n_r // n_shards, k_in,
                         n_shards)
    xspec = ExchangeSpec(parcel_cap=cap)
    spec = PaperNeuroSpec(n_neurons=n_r, k_in=k_in, ev_cap=16, t_end=100.0)
    fn, args, sh = build_fap_round(model, spec, mesh, optimized=True,
                                   transport="sparse_ragged", exchange=xspec,
                                   net=net_r)
    txt = jax.jit(fn, in_shardings=sh).lower(*args).compile().as_text()
    from repro.distributed.exchange import class_tag
    ladder = xspec.class_ladder()
    by_class = collective_channel_bytes(
        txt, tags=tuple(class_tag(c) for c in ladder))
    per_class = [by_class[class_tag(c)] for c in ladder]
    static = parcel[("sparse", "high", n_r)]
    emit(f"exchange/bytes/ragged_classes/n{n_r}", 0.0,
         f"ladder={list(ladder)};per_class={per_class};static={static}")
    if not (per_class == sorted(per_class) and per_class[-1] == static
            and all(b < static for b in per_class[:-1]) and per_class[0] > 0):
        raise AssertionError(
            f"ragged class ladder bytes malformed: {per_class} vs static "
            f"{static}")
    # driven quiet run: the counts phase must route ~every round through
    # the smallest class
    iinj_q = regime_iinj(n_r, "quiet", seed=1)
    kw = dict(mesh=mesh, optimized=True, exchange=xspec, max_rounds=8,
              ev_cap=16)
    res_s, r_s = run_fap_spmd(model, net_r, iinj_q, 4.0, transport="sparse",
                              **kw)
    res_g, r_g = run_fap_spmd(model, net_r, iinj_q, 4.0,
                              transport="sparse_ragged", **kw)
    sb = res_s.comm["parcel_bytes"]
    rb = res_g.comm["parcel_bytes"]
    emit("exchange/ragged/realized_quiet", 0.0,
         f"ragged={rb};static={sb};rounds={r_g};"
         f"tightening={sb / max(1, rb):.2f}x")
    if not (r_s == r_g and 0 < rb < sb):
        raise AssertionError(
            f"ragged transport did not tighten quiet-run parcel bytes: "
            f"{rb} vs static {sb} ({r_g}/{r_s} rounds)")
    if rb > r_g * per_class[-1]:
        raise AssertionError(
            f"ragged realized bytes exceed the static cap: {rb} > "
            f"{r_g * per_class[-1]}")


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker()
    else:
        run()

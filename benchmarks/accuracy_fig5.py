"""Paper Fig. 5: voltage-trajectory accuracy and step counts, Backward Euler
(dt = 25us / 5us / 1us ref) vs BDF (atol 1e-2 / 1e-3 / 1e-4 ref), on a
single-spike window and a longer multi-spike run (phase-shift accumulation).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import calibration, emit, soma_model, timeit
from repro.core import bdf
from repro.core.calibrate import spikes_in_trace
from repro.core.fixed_step import run_fixed


def _spike_times(ts, vs, thr=-20.0):
    out = []
    for i in range(1, len(ts)):
        if vs[i - 1] <= thr < vs[i]:
            f = (thr - vs[i - 1]) / (vs[i] - vs[i - 1])
            out.append(ts[i - 1] + f * (ts[i] - ts[i - 1]))
    return np.array(out)


def _fixed_curve(model, iinj, T, dt):
    y0 = model.init_state()
    (_, ns, tr), secs = timeit(
        lambda: run_fixed(model, y0, T, iinj, method="cnexp", dt=dt,
                          record_every=1))
    ts = np.arange(1, ns + 1) * dt
    return ts, np.asarray(tr), ns, secs


def _bdf_curve(model, iinj, T, atol):
    opts = bdf.BDFOptions(atol=atol)
    st0 = bdf.reinit(model, 0.0, model.init_state(), iinj, opts)
    stepf = jax.jit(lambda s: bdf.step(model, s, T, iinj, opts))
    stepf(st0)                                     # compile
    import time
    t0 = time.time()
    st = st0
    ts, vs = [0.0], [float(st.zn[0][0])]
    while float(st.t) < T and not bool(st.failed):
        st = stepf(st)
        ts.append(float(st.t))
        vs.append(float(st.zn[0][model.idx_vsoma]))
    return np.array(ts), np.array(vs), int(st.nst), time.time() - t0


def run(T: float = 100.0) -> None:
    model = soma_model()
    iinj = 1.3 * calibration()["i_threshold"]      # suprathreshold clamp
    ts_ref, vs_ref, ns_ref, _ = _fixed_curve(model, iinj, T, 0.001)
    s_ref = _spike_times(ts_ref, vs_ref)

    for dt in (0.025, 0.005):
        ts, vs, ns, secs = _fixed_curve(model, iinj, T, dt)
        s = _spike_times(ts, vs)
        n = min(len(s), len(s_ref))
        shift = np.abs(s[:n] - s_ref[:n]).max() if n else float("nan")
        emit(f"fig5/euler_dt{dt*1000:.0f}us", secs * 1e6,
             f"steps={ns};spikes={len(s)};max_phase_shift_ms={shift:.4f}")

    for atol in (1e-2, 1e-3, 1e-4):
        ts, vs, ns, secs = _bdf_curve(model, iinj, T, atol)
        s = _spike_times(ts, vs)
        n = min(len(s), len(s_ref))
        shift = np.abs(s[:n] - s_ref[:n]).max() if n else float("nan")
        emit(f"fig5/cvode_atol{atol:g}", secs * 1e6,
             f"steps={ns};spikes={len(s)};max_phase_shift_ms={shift:.4f};"
             f"step_reduction_vs_25us={int(T/0.025)/max(ns,1):.1f}x")


if __name__ == "__main__":
    run()

"""Paper Fig. 10 / §4.5: overall speed-up estimate for a mixed-regime
population — regime percentages from the laboratory-experiment census,
runtimes measured per regime, combined as the paper combines them:
   T_solver = sum_r pct_r * t_solver(r);  speedup = T_2a / T_2c(+variants).
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import emit, regime_iinj, soma_model
from repro.core import bdf, exec_bsp, exec_fap, network
from benchmarks.lab_experiment_fig8 import PCTS

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))
N = 64 if QUICK else 128
T = 25.0 if QUICK else 50.0
OPTS = bdf.BDFOptions(atol=1e-3)


def _wall(make):
    import jax
    runner = make()
    jax.block_until_ready(runner())
    t0 = time.time()
    jax.block_until_ready(runner())
    return time.time() - t0


def run() -> None:
    model = soma_model()
    net = network.make_network(N, k_in=16, seed=4)
    t_2a, t_2c, t_eg2, t_eg1 = 0.0, 0.0, 0.0, 0.0
    for regime, pct in PCTS.items():
        iinj = regime_iinj(N, regime, seed=7)
        w2a = _wall(lambda: exec_bsp.make_bsp_fixed_runner(
            model, net, iinj, T, method="derivimplicit"))
        w2c = _wall(lambda: exec_fap.make_fap_vardt_runner(
            model, net, iinj, T, opts=OPTS))
        weg2 = _wall(lambda: exec_fap.make_fap_vardt_runner(
            model, net, iinj, T, opts=OPTS, eg_window=0.0125))
        weg1 = _wall(lambda: exec_fap.make_fap_vardt_runner(
            model, net, iinj, T, opts=OPTS, eg_window=0.025))
        t_2a += pct * w2a
        t_2c += pct * w2c
        t_eg2 += pct * weg2
        t_eg1 += pct * weg1
        emit(f"fig10/{regime}", w2c * 1e6,
             f"pct={pct};t2a_s={w2a:.3f};t2c_s={w2c:.3f};"
             f"t2c_eg2_s={weg2:.3f};t2c_eg1_s={weg1:.3f}")
    emit("fig10/overall", t_2c * 1e6,
         f"speedup_precise={t_2a/max(t_2c,1e-12):.2f}x;"
         f"speedup_eg_half={t_2a/max(t_eg2,1e-12):.2f}x;"
         f"speedup_eg_full={t_2a/max(t_eg1,1e-12):.2f}x")


if __name__ == "__main__":
    run()

"""Shared benchmark utilities: timed runs, CSV emission, cached calibration.

Scaling note (DESIGN.md §7): this container is one CPU core; networks are
scaled (64-512 neurons, 50-250 ms biological time) with the paper's regime
structure preserved.  Reported quantities are step counts, event counts and
wall-clock ratios — the same quantities the paper reports.
"""
from __future__ import annotations

import functools
import json
import os
import time
import zlib

import numpy as np

from repro.core import morphology
from repro.core.calibrate import current_for_rate, threshold_current
from repro.core.cell import CellModel

CACHE = os.path.join(os.path.dirname(__file__), "_calibration.json")

# the five regimes of paper §4
REGIMES = {"quiet": 0.25, "slow": 1.5, "moderate": 6.5, "fast": 38.0,
           "burst": 55.8}

# structured-record accumulator behind the CSV stream: each suite's run()
# calls dump_json at its end; with REPRO_BENCH_JSON set the records land in
# BENCH_<suite>.json (the nightly CI uploads these as artifacts, so the
# perf trajectory is recorded instead of lost in job logs).
_RECORDS: list = []
_FLUSHED = 0


def emit(name: str, us_per_call: float, derived: str):
    _RECORDS.append({"name": name, "us_per_call": float(us_per_call),
                     "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")


def record_csv(text: str):
    """Fold a subprocess worker's CSV stdout into the record accumulator
    (the worker's emit() prints land in a pipe, not this process)."""
    for ln in text.splitlines():
        parts = ln.split(",", 2)
        if len(parts) != 3:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        _RECORDS.append({"name": parts[0], "us_per_call": us,
                         "derived": parts[2]})


def dump_json(suite: str):
    """Write records accumulated since the previous dump to
    BENCH_<suite>.json (no-op unless REPRO_BENCH_JSON is set, or when no
    new records arrived — a suite that already dumped internally must not
    be clobbered by the orchestrator's per-suite dump)."""
    global _FLUSHED
    recs, _FLUSHED = _RECORDS[_FLUSHED:], len(_RECORDS)
    if not recs or not os.environ.get("REPRO_BENCH_JSON"):
        return
    out_dir = os.environ.get("REPRO_BENCH_DIR", ".")
    payload = {"suite": suite,
               "quick": os.environ.get("REPRO_BENCH_QUICK") == "1",
               "records": recs}
    with open(os.path.join(out_dir, f"BENCH_{suite}.json"), "w") as f:
        json.dump(payload, f, indent=2)


@functools.lru_cache(maxsize=None)
def soma_model() -> CellModel:
    return CellModel(morphology.soma_only())


@functools.lru_cache(maxsize=None)
def branched_model() -> CellModel:
    """Small L5-pyramidal-like tree (the paper's single-cell experiments)."""
    return CellModel(morphology.branched_tree(depth=2, seg_per_branch=2))


def calibration(model_kind: str = "soma") -> dict:
    """Threshold current, onset-rate current and measured onset rate.

    The classic HH soma is type-II excitable: under DC drive it cannot fire
    below the ~50 Hz onset rate, so low *network* regimes (0.25-6.5 Hz mean)
    are realised as population mixtures — a fraction of neurons at onset
    rate, the rest just below threshold (recorded in DESIGN.md §8)."""
    cache = {}
    if os.path.exists(CACHE):
        cache = json.load(open(CACHE))
    if model_kind in cache:
        return cache[model_kind]
    from repro.core.calibrate import _n_spikes
    model = soma_model() if model_kind == "soma" else branched_model()
    i_th = threshold_current(model)
    i_active = current_for_rate(model, 45.0, i_th, t_end=1000.0)
    r_active = _n_spikes(model, i_active, 1000.0)
    i_burst = current_for_rate(model, 58.0, i_th, t_end=1000.0)
    r_burst = _n_spikes(model, i_burst, 1000.0)
    entry = {"i_threshold": i_th, "i_active": i_active,
             "r_active_hz": float(r_active), "i_burst": i_burst,
             "r_burst_hz": float(r_burst)}
    cache[model_kind] = entry
    json.dump(cache, open(CACHE, "w"), indent=2)
    return entry


def regime_iinj(n: int, regime: str, seed: int = 0,
                model_kind: str = "soma") -> np.ndarray:
    """Per-neuron currents whose population mean rate matches the regime.

    The regime name is folded into the rng seed with a *deterministic*
    hash (crc32): python's ``hash()`` is salted per process, which made
    spike counts differ across processes for the same arguments —
    cross-process benchmark comparisons (nightly BENCH json vs local
    runs, orchestrator vs worker) silently compared different networks.
    """
    cal = calibration(model_kind)
    rng = np.random.default_rng(seed + zlib.crc32(regime.encode()) % 1000)
    target = REGIMES[regime]
    if regime == "burst":
        base = np.full(n, cal["i_burst"])
        return base * (1.0 + 0.01 * rng.standard_normal(n))
    frac = min(1.0, target / max(cal["r_active_hz"], 1.0))
    active = rng.random(n) < frac
    iinj = np.where(active, cal["i_active"], 0.80 * cal["i_threshold"])
    return iinj * (1.0 + 0.01 * rng.standard_normal(n))


def timeit(fn, repeats: int = 1):
    """(result, seconds) with one warm-up call (compile excluded)."""
    import jax
    jax.block_until_ready(fn())
    t0 = time.time()
    for _ in range(repeats):
        out = jax.block_until_ready(fn())
    return out, (time.time() - t0) / repeats

"""Paper Fig. 3: synaptic-delay distribution census on the synthetic network
(0.1 ms bins; fraction at the BSP communication interval; tail mass)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import network


def run(n: int = 4096, k_in: int = 16) -> None:
    net = network.make_network(n, k_in=k_in, seed=0)
    hist, edges = network.delay_histogram(net)
    d = net.delay
    frac_min = float((d <= network.MIN_DELAY + 0.05).mean())
    frac_tail = float((d >= network.MAX_DELAY - 1e-9).mean())
    emit("fig3/delays", 0.0,
         f"n_synapses={d.size};min_ms={d.min():.3f};median_ms={np.median(d):.3f};"
         f"mode_bin_ms={edges[np.argmax(hist)]:.2f};"
         f"frac_at_bsp_interval={frac_min:.4f};frac_at_7ms_cap={frac_tail:.4f}")


if __name__ == "__main__":
    run()

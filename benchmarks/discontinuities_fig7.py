"""Paper Fig. 7: step count + runtime vs discontinuity (synaptic event) rate
— the paper's key sensitivity: CVODE wins below ~1-1.6 kHz of events, where
each event resets the IVP.  Events at fixed frequency, three weights."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, soma_model, timeit
from repro.core import bdf
from repro.core.fixed_step import make_stepper

FREQS = [10.0, 30.0, 100.0, 300.0, 1000.0, 3000.0]     # events / second
WEIGHTS = [2e-4, 1e-3, 5e-3]                           # uS per event


def _bdf_run(model, T, freq, w, atol=1e-3):
    period = 1000.0 / freq
    opts = bdf.BDFOptions(atol=atol)
    n_ev = int(T / period)
    adv = jax.jit(lambda st, tl: bdf.advance_to(model, st, tl, 0.0, opts))
    dlv = jax.jit(lambda st: bdf.deliver_event(model, st, w, 0.0, 0.0, opts))

    def run():
        st = bdf.reinit(model, 0.0, model.init_state(), 0.0, opts)
        for k in range(1, n_ev + 1):
            st = adv(st, k * period)
            st = dlv(st)
        return adv(st, T)

    return timeit(run)


def _euler_run(model, T, freq, w, dt=0.025):
    period = 1000.0 / freq
    step = make_stepper(model, "cnexp", dt)
    n = int(T / dt)

    def body(y, i):
        t = i * dt
        hit = jnp.floor((t + dt) / period) > jnp.floor(t / period)
        y = model.apply_event(y, jnp.where(hit, w, 0.0), 0.0)
        return step(y, 0.0), None

    runner = jax.jit(lambda y0: jax.lax.scan(body, y0, jnp.arange(n))[0])
    return timeit(lambda: runner(model.init_state())), n


def run(T: float = 250.0) -> None:
    model = soma_model()
    for w in WEIGHTS:
        for freq in FREQS:
            st, secs_b = _bdf_run(model, T, freq, w)
            (_, secs_e), n_fixed = _euler_run(model, T, freq, w)
            nst = int(st.nst)
            emit(f"fig7/w{w:g}_f{freq:g}Hz", secs_b * 1e6,
                 f"cvode_steps={nst};resets={int(st.nreset)};"
                 f"euler_steps={n_fixed};step_ratio={n_fixed/max(nst,1):.1f}x;"
                 f"runtime_ratio={secs_e/max(secs_b,1e-9):.2f}x")


if __name__ == "__main__":
    run()

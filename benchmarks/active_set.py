"""Active-set compaction benchmark (ISSUE 4) + activity-proportional
delivery axis (ISSUE 5): per-round wall time of the FAP vardt scheduler
round across the firing regimes of Fig. 9 at N = 1k..64k (quick: 1k..4k).

Stepping axis (ISSUE 4): the dense path vmaps the full step machinery
over all N neurons every round, so its round time grows linearly in N
whether 2% or 100% of the lanes do useful work; the compact path gathers
a fixed [batch_cap] batch of the earliest runnable lanes and scatters
results back, so at fixed cap its round time is ~flat in N.

Delivery axis (ISSUE 5): on a spiking round the dense path pays the
O(E) fan-out + insert no matter how few lanes spiked.
``fanout="compact"`` gathers only the spiking lanes' out-edges (a fixed
[spike_cap * k_out] batch through the flat batch insert) so the delivery
stage, too, is ~flat in N.  The stage is timed standalone (a jitted
fori_loop repeatedly inserting a fixed spike_cap-wide spiking set, carry
in place — the same stage-isolation methodology as the PR 1 event-wheel
insert bench): timing whole bursty rounds would bury the contrast under
the BDF stepping cost and, worse, under the scheduler's warm-up
transient, where thousands of early rounds are spike-free and the
fan-out cond never fires.

Asserted, not assumed:
  * compact (batch AND fan-out) is event-for-event identical to dense on
    a full run in every regime incl. burst, and a forced batch_cap
    overflow rolls work to later rounds without drops (deterministic —
    asserted in quick mode / per-PR CI too),
  * quiet regime: per-round time at fixed batch_cap grows <= 1.5x from
    N=1k to N=16k while dense grows >= 4x (CPU),
  * delivery stage at a fixed 256-lane spiking set: compact fan-out
    grows <= 2.5x from N=1k to N=16k while dense grows >= 4x.
    Growth-ratio bounds are timing-based and only enforced in the full
    (nightly) run; quick mode asserts just the wide-margin
    compact-vs-dense speedups so a contended CI runner cannot flake the
    per-PR gate.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import dump_json, emit, regime_iinj, soma_model, timeit

K_IN = 16
BATCH_CAP = 256
T_END_IDENT = 40.0


def _round_timer(model, net, iinj, warm_rounds: int = 4, span: int = 6,
                 repeats: int = 3, **kw):
    """Seconds per scheduler round, timed from a warmed-up state (queues
    populated, clocks spread) rather than the all-zero init.

    ``span`` consecutive rounds run inside ONE jitted fori_loop so the
    carry updates stay in-place, exactly as inside the production
    while_loop — timing round_body through a fresh jit boundary per round
    would charge both paths an O(N) carry copy that the real runner never
    pays."""
    import jax

    from repro.core import exec_fap

    run = exec_fap.make_fap_vardt_runner(model, net, iinj, t_end=1e9,
                                         max_rounds=1_000_000_000, **kw)
    carry = jax.jit(run.init_carry)()
    warm = jax.jit(lambda c: jax.lax.fori_loop(
        0, warm_rounds, lambda i, cc: run.round_body(cc), c))
    burst = jax.jit(lambda c: jax.lax.fori_loop(
        0, span, lambda i, cc: run.round_body(cc), c))
    carry = jax.block_until_ready(warm(carry))
    _, secs = timeit(lambda: burst(carry), repeats=repeats)
    return secs / span


def _delivery_timer(net, fanout: str, spikers: int = BATCH_CAP,
                    iters: int = 8, repeats: int = 3):
    """Seconds per delivery stage (fan-out + queue insert of one spiking
    round), timed inside a jitted fori_loop so the queue carry updates
    in place — exactly as inside the production round.  The spiking set
    is a fixed ``spikers``-wide mask (the batch cap bounds per-round
    spikes on the compact path), times jittered per iteration so the
    inserts cannot be CSE'd away."""
    import jax
    import jax.numpy as jnp

    from repro import sched
    from repro.core import exec_common as xc

    n = int(net.n)
    dnet = xc.to_device(net)
    qops = sched.get_queue_ops("dense", ev_cap=64)
    qinsert = sched.edge_insert(qops, net)
    ins = xc.make_spike_insert(net, dnet, qops, qinsert, fanout,
                               min(spikers, n))
    rng = np.random.default_rng(1)
    lanes = rng.choice(n, min(spikers, n), replace=False)
    spiked = jnp.zeros((n,), bool).at[jnp.asarray(lanes)].set(True)
    t_sp = jnp.asarray(rng.uniform(0.0, 0.5, n))
    loop = jax.jit(lambda eq: jax.lax.fori_loop(
        0, iters, lambda i, e: ins(e, spiked, t_sp + 1e-6 * i), eq))
    eq0 = qops.make(n)
    jax.block_until_ready(loop(eq0))                 # compile + warm
    _, secs = timeit(lambda: loop(eq0), repeats=repeats)
    return secs / iters


def run() -> None:
    import jax

    from repro.core import exec_common as xc
    from repro.core import exec_fap, network

    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    model = soma_model()
    sizes = [1024, 4096] if quick else [1024, 4096, 16384, 65536]
    lo_n, hi_n = sizes[0], (4096 if quick else 16384)
    compact_max, dense_min = 1.5, 4.0     # growth bounds, full mode only

    # ---- event-for-event identity, low/high/burst regimes (both compact
    # knobs on: stepping batch AND delivery fan-out) -----------------------
    n_id = 256
    net_id = network.make_network(n_id, k_in=K_IN, seed=7)
    for regime in ("quiet", "fast", "burst"):
        iinj = regime_iinj(n_id, regime, seed=1)
        r_d, rounds_d = exec_fap.make_fap_vardt_runner(
            model, net_id, iinj, T_END_IDENT)()
        r_c, rounds_c = exec_fap.make_fap_vardt_runner(
            model, net_id, iinj, T_END_IDENT, batch="compact",
            fanout="compact")()
        same = (np.array_equal(np.asarray(r_d.rec.times),
                               np.asarray(r_c.rec.times))
                and np.array_equal(np.asarray(r_d.rec.count),
                                   np.asarray(r_c.rec.count))
                and int(rounds_d) == int(rounds_c)
                and int(r_c.dropped) == 0 and not bool(r_c.failed))
        m = xc.sched_metrics(r_c.sched)
        emit(f"active_set/identity/{regime}", 0.0,
             f"identical={same};n={n_id};rounds={int(rounds_c)};"
             f"spikes={int(r_c.rec.count.sum())};"
             f"occupancy={m['occupancy']:.3f}")
        if not same:
            raise AssertionError(
                f"compact != dense spike trains ({regime} regime)")
        # a forced overflow must roll, never drop
        r_o, rounds_o = exec_fap.make_fap_vardt_runner(
            model, net_id, iinj, T_END_IDENT, batch="compact",
            batch_cap=32)()
        assert int(r_o.dropped) == 0 and not bool(r_o.failed), regime
        assert int(rounds_o) > int(rounds_c), regime
        emit(f"active_set/overflow_rolls/{regime}", 0.0,
             f"rounds={int(rounds_o)}_vs_{int(rounds_c)};dropped=0")

    # ---- per-round wall time scaling -------------------------------------
    times: dict = {}
    for n in sizes:
        net = network.make_network(n, k_in=K_IN, seed=7)
        iinj = regime_iinj(n, "quiet", seed=1)
        s_d = _round_timer(model, net, iinj)
        s_c = _round_timer(model, net, iinj, batch="compact",
                           batch_cap=BATCH_CAP)
        times[n] = (s_d, s_c)
        emit(f"active_set/dense_round/n{n}", s_d * 1e6, "regime=quiet")
        emit(f"active_set/compact_round/n{n}", s_c * 1e6,
             f"cap={BATCH_CAP};speedup_vs_dense={s_d / s_c:.2f}x")

    g_dense = times[hi_n][0] / times[lo_n][0]
    g_compact = times[hi_n][1] / times[lo_n][1]
    speedup_hi = times[hi_n][0] / times[hi_n][1]
    emit("active_set/scaling", 0.0,
         f"span=n{lo_n}->n{hi_n};dense_growth={g_dense:.2f}x;"
         f"compact_growth={g_compact:.2f}x;"
         f"quiet_speedup_at_n{hi_n}={speedup_hi:.1f}x")
    if quick:
        # wide margin (measured ~20x+): robust to contended CI runners
        assert speedup_hi >= 2.0, \
            f"compact should beat dense at n{hi_n}: {speedup_hi:.2f}x"
    else:
        assert g_dense >= dense_min, \
            f"dense round time should grow ~linearly in N: {g_dense:.2f}x"
        assert g_compact <= compact_max, \
            f"compact round time should be ~flat in N: {g_compact:.2f}x"

    # ---- delivery axis (ISSUE 5): spiking-round delivery stage -----------
    # a fixed spike_cap-wide spiking set inserted repeatedly (carry in
    # place); only the fan-out differs, so the contrast isolates the
    # O(E)-per-spiking-round delivery cost the compact path removes
    dtimes: dict = {}
    for n in sizes:
        net = network.make_network(n, k_in=K_IN, seed=7)
        s_df = _delivery_timer(net, "dense")
        s_cf = _delivery_timer(net, "compact")
        dtimes[n] = (s_df, s_cf)
        emit(f"active_set/dense_fanout_insert/n{n}", s_df * 1e6,
             f"spikers={BATCH_CAP}")
        emit(f"active_set/compact_fanout_insert/n{n}", s_cf * 1e6,
             f"spikers={BATCH_CAP};speedup_vs_dense_fanout={s_df / s_cf:.2f}x")
    gf_dense = dtimes[hi_n][0] / dtimes[lo_n][0]
    gf_compact = dtimes[hi_n][1] / dtimes[lo_n][1]
    f_speedup = dtimes[hi_n][0] / dtimes[hi_n][1]
    emit("active_set/fanout_scaling", 0.0,
         f"span=n{lo_n}->n{hi_n};dense_fanout_growth={gf_dense:.2f}x;"
         f"compact_fanout_growth={gf_compact:.2f}x;"
         f"delivery_speedup_at_n{hi_n}={f_speedup:.1f}x")
    if quick:
        assert f_speedup >= 1.5, \
            f"compact fan-out should beat dense at n{hi_n}: {f_speedup:.2f}x"
    else:
        assert gf_dense >= 4.0, \
            f"dense fan-out stage should grow ~linearly: {gf_dense:.2f}x"
        assert gf_compact <= 2.5, \
            f"compact fan-out stage should be ~flat: {gf_compact:.2f}x"
    dump_json("active_set")


if __name__ == "__main__":
    run()

"""Paper Fig. 9: time-to-solution of six solvers across the five spiking
regimes and increasing network size.

Solvers (paper labels):
  1a bsp_cnexp      1b bsp_euler       1c fap_euler
  2a bsp_derivimpl  2b bsp_cvode       2c fap_cvode
  2c-eg2 fap_cvode + half-dt event grouping
  2c-eg1 fap_cvode + full-dt event grouping

Wall-clock excludes compilation (runner called twice; second call timed),
matching the paper's steady-state time-to-solution measure.  Sizes/durations
are scaled for one CPU core (DESIGN.md §7); ratios are the reported result.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import emit, regime_iinj, soma_model
from repro.core import bdf, exec_bsp, exec_fap, network

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))
FULL = bool(int(os.environ.get("REPRO_BENCH_FULL", "0")))
# compile count dominates on 1 CPU core: 8 solvers x 5 regimes x |SIZES|
SIZES = [64] if QUICK else ([64, 256] if FULL else [64])
REGIME_T = {"quiet": 100.0, "slow": 50.0, "moderate": 50.0,
            "fast": 25.0, "burst": 25.0}
if QUICK:
    REGIME_T = {k: min(v, 25.0) for k, v in REGIME_T.items()}

OPTS = bdf.BDFOptions(atol=1e-3)


def _solvers(model, net, iinj, T):
    return {
        "1a_bsp_cnexp": lambda: exec_bsp.make_bsp_fixed_runner(
            model, net, iinj, T, method="cnexp"),
        "1b_bsp_euler": lambda: exec_bsp.make_bsp_fixed_runner(
            model, net, iinj, T, method="euler"),
        "1c_fap_euler": lambda: exec_fap.make_fap_fixed_runner(
            model, net, iinj, T, method="euler"),
        "2a_bsp_derivimpl": lambda: exec_bsp.make_bsp_fixed_runner(
            model, net, iinj, T, method="derivimplicit"),
        "2b_bsp_cvode": lambda: exec_bsp.make_bsp_vardt_runner(
            model, net, iinj, T, opts=OPTS),
        "2c_fap_cvode": lambda: exec_fap.make_fap_vardt_runner(
            model, net, iinj, T, opts=OPTS),
        "2c_eg2_fap_cvode": lambda: exec_fap.make_fap_vardt_runner(
            model, net, iinj, T, opts=OPTS, eg_window=0.0125),
        "2c_eg1_fap_cvode": lambda: exec_fap.make_fap_vardt_runner(
            model, net, iinj, T, opts=OPTS, eg_window=0.025),
    }


def _run_one(make):
    import jax
    runner = make()
    jax.block_until_ready(runner())      # compile + run
    t0 = time.time()
    out = jax.block_until_ready(runner())  # timed
    secs = time.time() - t0
    res = out if isinstance(out, exec_bsp.RunResult) else out[0]
    return res, secs


def run() -> None:
    model = soma_model()
    for n in SIZES:
        net = network.make_network(n, k_in=16, seed=1)
        for regime, T in REGIME_T.items():
            iinj = regime_iinj(n, regime, seed=n)
            ref_secs = None
            for name, make in _solvers(model, net, iinj, T).items():
                res, secs = _run_one(make)
                if name == "2a_bsp_derivimpl":
                    ref_secs = secs
                speed = (f";speedup_vs_2a={ref_secs/max(secs,1e-12):.2f}x"
                         if ref_secs and name.startswith("2c") else "")
                emit(f"fig9/{regime}_n{n}_{name}", secs * 1e6 / max(T, 1e-9),
                     f"t_bio_ms={T};wall_s={secs:.3f};steps={int(res.n_steps)};"
                     f"events={int(res.n_events)};resets={int(res.n_resets)};"
                     f"spikes={int(res.rec.count.sum())};"
                     f"failed={bool(res.failed)};dropped={int(res.dropped)}"
                     + speed)


if __name__ == "__main__":
    run()

"""Benchmark orchestrator — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.  REPRO_BENCH_QUICK=1 trims sizes."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (accuracy_fig5, active_set, delays_fig3,
                            discontinuities_fig7, event_wheel, exchange,
                            lab_experiment_fig8, placement, regimes_fig9,
                            robustness, roofline, serve, solver,
                            speedup_fig10, stiffness_fig6)
    modules = [
        ("fig3", delays_fig3.run),
        ("fig5", accuracy_fig5.run),
        ("fig6", stiffness_fig6.run),
        ("fig7", discontinuities_fig7.run),
        ("fig8", lab_experiment_fig8.run),
        ("fig9", regimes_fig9.run),
        ("fig10", speedup_fig10.run),
        ("event_wheel", event_wheel.run),
        ("exchange", exchange.run),
        ("placement", placement.run),
        ("active_set", active_set.run),
        ("solver", solver.run),
        ("robustness", robustness.run),
        ("serve", serve.run),
        ("roofline", lambda: roofline.run(mesh="all")),
    ]
    from benchmarks.common import dump_json

    failures = 0
    for name, fn in modules:
        try:
            fn()
        except Exception:                                   # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0,{traceback.format_exc(limit=1).strip()!r}",
                  file=sys.stderr)
        # flush this suite's records to BENCH_<name>.json (no-op for suites
        # that already dumped internally, or without REPRO_BENCH_JSON)
        dump_json(name)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()

"""Roofline analysis from the dry-run artifacts (assignment §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  The dry-run records *per-device* quantities (compiled
SPMD modules carry local shapes), so:

  compute term    = hlo_flops_per_device / 197e12          [s]
  memory term     = hlo_bytes_per_device / 819e9           [s]
  collective term = collective_bytes_per_device / 50e9     [s]

dominant = argmax; roofline fraction = (model_flops / chips / 197e12)
divided by the dominant term — the fraction of peak the step would sustain
if it ran exactly at the roofline bound.  model_flops_ratio catches
remat/redundancy waste (MODEL_FLOPS / total HLO flops).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_CSV = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "roofline.csv")


def analyse(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    flops = rec.get("hlo_flops_per_device") or rec["cost_raw"]["flops"]
    bytes_ = rec.get("hlo_bytes_per_device") or rec["cost_raw"]["bytes_accessed"]
    coll = rec.get("collective_bytes_per_device", 0)
    chips = rec.get("n_chips", 256)
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    t_step = max(terms.values())
    mf = rec.get("model_flops", 0.0)
    useful = mf / chips / PEAK_FLOPS
    frac = useful / t_step if t_step > 0 else 0.0
    ratio = mf / (flops * chips) if flops else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips, "compute_s": t_c, "memory_s": t_m,
        "collective_s": t_x, "dominant": dom, "roofline_fraction": frac,
        "model_flops_ratio": ratio,
        "mem_temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "mem_args_gib": rec["memory"]["argument_bytes"] / 2**30,
    }


def run(mesh: str = "single") -> None:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.load(open(path))
        row = analyse(rec)
        if row is None or (mesh != "all" and row["mesh"] != mesh):
            continue
        rows.append(row)
        emit(f"roofline/{row['arch']}_{row['shape']}_{row['mesh']}",
             max(row["compute_s"], row["memory_s"], row["collective_s"]) * 1e6,
             f"compute_s={row['compute_s']:.3e};memory_s={row['memory_s']:.3e};"
             f"collective_s={row['collective_s']:.3e};dominant={row['dominant']};"
             f"roofline_fraction={row['roofline_fraction']:.3f};"
             f"model_flops_ratio={row['model_flops_ratio']:.3f};"
             f"temp_gib={row['mem_temp_gib']:.2f}")
    if rows:
        keys = list(rows[0])
        with open(OUT_CSV, "w") as f:
            f.write(",".join(keys) + "\n")
            for r in rows:
                f.write(",".join(str(r[k]) for k in keys) + "\n")


if __name__ == "__main__":
    run(mesh="all")

"""Paper Fig. 8 + §3.4: event census of a network simulation mimicking the
laboratory experiment — per-neuron discontinuity counts (top/median/bottom
1%), mean event rate, and inter-event silence statistics.

Heterogeneous drive: neurons are assigned to the five regimes with the
paper's Fig. 10 percentages (31.43/38.44/27.02/3.10/0.01)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import REGIMES, calibration, emit, soma_model, timeit
from repro.core import exec_bsp, network

PCTS = {"quiet": 0.3143, "slow": 0.3844, "moderate": 0.2702,
        "fast": 0.0310, "burst": 0.0001}


def mixture_currents(n: int, seed: int = 0) -> np.ndarray:
    from benchmarks.common import regime_iinj
    rng = np.random.default_rng(seed)
    names = list(PCTS)
    probs = np.array([PCTS[k] for k in names])
    probs = probs / probs.sum()
    assign = rng.choice(len(names), size=n, p=probs)
    cur = np.empty(n)
    for i, name in enumerate(names):
        mask = assign == i
        if mask.any():
            cur[mask] = regime_iinj(int(mask.sum()), name, seed=seed + i)
    return cur, assign


def run(n: int = 256, t_end: float = 250.0) -> None:
    model = soma_model()
    net = network.make_network(n, k_in=16, seed=2)
    iinj, assign = mixture_currents(n)
    res, secs = timeit(lambda: exec_bsp.run_bsp_fixed(
        model, net, iinj, t_end, method="cnexp"))
    counts = np.zeros(n)
    # events received per neuron = spikes of pres fanned in
    spikes = np.asarray(res.rec.count)
    for pre, post in zip(net.pre, net.post):
        counts[post] += spikes[pre]
    order = np.sort(counts)
    mean_hz = counts.mean() / (t_end * 1e-3)
    top1 = order[-max(1, n // 100):].mean() / (t_end * 1e-3)
    med1 = np.median(order) / (t_end * 1e-3)
    bot1 = order[: max(1, n // 100)].mean() / (t_end * 1e-3)
    emit("fig8/event_census", secs * 1e6,
         f"total_events={int(counts.sum())};mean_event_hz={mean_hz:.1f};"
         f"top1pct_hz={top1:.1f};median_hz={med1:.1f};bottom1pct_hz={bot1:.1f};"
         f"below_1kHz_break_even={mean_hz < 1000};"
         f"spikes={int(spikes.sum())}")


if __name__ == "__main__":
    run()

"""Multi-tenant simulation serving (ISSUE 10): admission latency,
rounds-per-tenant under mixed QoS, and quarantine overhead vs a
fault-free batch — each asserted, all checkpoint/queue state in
hermetic tmpdirs.

  serve/admission_latency     wall cost of admitting one tenant into a
                              freed lane (fresh carry init + lane write).
  serve/round_mixed_qos       per-service-round wall time with a mixed
                              QoS batch; derived reports rounds-per-
                              tenant per class (the frontier cap's
                              throttle, asserted slower for the capped
                              class).
  serve/quarantine_overhead   end-to-end wall overhead of a poison ->
                              quarantine -> backoff -> retry cycle vs
                              the fault-free batch, asserting the CI
                              acceptance: the poisoned tenant completes
                              bit-identically to its solo run, every
                              other tenant bit-identically to the
                              fault-free batch, and an overloaded queue
                              sheds only lowest-QoS with explicit
                              rejection counts — zero silent drops.

Quick mode (REPRO_BENCH_QUICK=1) trims lanes/network/horizon for
check.sh.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import dump_json, emit, soma_model
from repro.checkpoint import ExponentialBackoff, FaultPlan
from repro.core import exec_fap, network
from repro.serve import SimService, TenantRequest

QUICK = os.environ.get("REPRO_BENCH_QUICK") == "1"


def _reqs(k, **kw):
    return [TenantRequest(rid=r, iinj=0.14 + 0.01 * (r % 5), **kw)
            for r in range(k)]


def run():
    n = 16 if QUICK else 32
    t_end = 5.0 if QUICK else 10.0
    lanes = 3 if QUICK else 4
    model = soma_model()
    net = network.make_network(n, k_in=4, seed=3)
    runner = exec_fap.make_fap_vardt_runner(model, net, 0.0, t_end)

    def svc(**kw):
        kw.setdefault("lanes", lanes)
        return SimService(runner=runner, t_end=t_end, **kw)

    # --- warm: compile the vmapped round + fault-free baseline ------------
    s = svc()
    for r in _reqs(lanes):
        s.submit(r)
    s.run()                                   # compile + warm
    s = svc()
    for r in _reqs(lanes):
        s.submit(r)
    t0 = time.perf_counter()
    base = s.run()
    base_s = time.perf_counter() - t0
    assert base.completed == lanes and base.rejected == 0
    base_rounds = base.rounds

    # --- admission latency: one tenant into a freed lane ------------------
    s = svc()
    t0 = time.perf_counter()
    s.submit(TenantRequest(rid=0, iinj=0.15))
    s._admit()
    import jax
    jax.block_until_ready(s._carry[0].t)
    admit_s = time.perf_counter() - t0
    emit("serve/admission_latency", admit_s * 1e6,
         f"lanes={lanes} n={n} fresh-carry init + masked lane write")

    # --- mixed QoS: capped class pays rounds, not starvation --------------
    cap = max(2, n // 8)
    s = svc(qos_caps={0: cap})
    mixed = [TenantRequest(rid=r, iinj=0.15, qos=r % 2)
             for r in range(lanes)]
    for r in mixed:
        s.submit(r)
    t0 = time.perf_counter()
    res = s.run()
    mixed_s = time.perf_counter() - t0
    assert res.completed == lanes, res
    r_lo = [res.results[r.rid].rounds for r in mixed if r.qos == 0]
    r_hi = [res.results[r.rid].rounds for r in mixed if r.qos == 1]
    assert min(r_lo) > max(r_hi), (r_lo, r_hi)   # cap throttles, never starves
    emit("serve/round_mixed_qos", mixed_s / max(1, res.rounds) * 1e6,
         f"cap={cap} rounds/tenant qos0={np.mean(r_lo):.0f} "
         f"qos1={np.mean(r_hi):.0f}")

    # --- quarantine overhead + the CI acceptance assertions ---------------
    solo = {}
    for r in _reqs(lanes):
        s = svc()
        s.submit(r)
        solo[r.rid] = s.run().results[r.rid]
    victim = 1
    fault = FaultPlan(poison_at_round=4, poison_tenant=victim,
                      poison_lane=1)
    s = svc(fault=fault, backoff=ExponentialBackoff(max_retries=3))
    for r in _reqs(lanes):
        s.submit(r)
    t0 = time.perf_counter()
    res = s.run()
    poison_s = time.perf_counter() - t0
    assert res.quarantines >= 1 and res.retried >= 1, res
    assert res.completed == lanes, res
    for rid in range(lanes):
        got, want = res.results[rid], base.results[rid]
        assert np.array_equal(got.times, want.times), f"tenant {rid} perturbed"
        assert np.array_equal(got.count, want.count)
        assert np.array_equal(got.times, solo[rid].times)
    emit("serve/quarantine_overhead", (poison_s - base_s) * 1e6,
         f"retries={res.retried} extra_rounds={res.rounds - base_rounds} "
         f"neighbours+solo bit-identical")

    # --- overload shedding: explicit, lowest-QoS only ---------------------
    s = svc(queue_cap=lanes)
    lo = [TenantRequest(rid=100 + r, iinj=0.15, qos=0) for r in range(lanes)]
    hi = [TenantRequest(rid=200 + r, iinj=0.15, qos=2) for r in range(lanes)]
    for r in lo + hi:
        s.submit(r)
    res = s.run()
    assert res.shed == lanes and res.rejected == lanes, res
    assert all(res.results[r.rid].status == "rejected" and
               res.results[r.rid].reason == "shed:queue_full" for r in lo)
    assert all(res.results[r.rid].status == "completed" for r in hi)
    res.assert_accounting()                      # zero silent drops

    dump_json("serve")


if __name__ == "__main__":
    run()

"""Event-queue insert microbenchmark: dense argsort vs bucketed event wheel.

The scheduler round's spike-parcel channel is one batched insert of
E = N * k_in candidate events per round.  The dense queue pays a global
stable argsort over E plus a per-neuron argsort over the capacity axis;
the wheel (repro.sched) pays O(E) scatter arithmetic.  Reported: µs per
insert call at N in {1k, 64k, 1M} (quick: {1k, 16k}), k_in = 16, plus a
jaxpr census proving the wheel path lowers without any ``sort`` primitive.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import dump_json, emit, timeit

K_IN = 16
SPIKE_FRAC = 0.10            # fraction of presynaptic neurons spiking / round


def _traffic(n: int, k: int, seed: int = 0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    E = n * k
    tgt = jnp.asarray(np.repeat(np.arange(n, dtype=np.int32), k))
    t = jnp.asarray(rng.uniform(0.0, 8.0, E))
    wa = jnp.asarray(rng.exponential(1e-4, E))
    wg = jnp.zeros((E,))
    valid = jnp.asarray(rng.random(E) < SPIKE_FRAC)
    return tgt, t, wa, wg, valid


def run() -> None:
    import jax
    import jax.numpy as jnp

    from repro import sched
    from repro.core import events as ev

    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    sizes = [1_000, 16_000] if quick else [1_000, 64_000, 1_000_000]
    spec = sched.WheelSpec(n_buckets=16, bucket_slots=4, bucket_width=0.5)
    repeats = 3 if quick else 5

    # one-time jaxpr census: the wheel insert must carry no sort primitive
    tgt, t, wa, wg, valid = _traffic(256, K_IN)
    w0 = sched.make_wheel(256, spec)
    p_generic = sched.jaxpr_primitives(
        lambda q: sched.insert(spec, q, tgt, t, wa, wg, valid), w0)
    p_grouped = sched.jaxpr_primitives(
        lambda q: sched.insert_grouped(spec, q, t.reshape(256, K_IN),
                                       wa.reshape(256, K_IN),
                                       wg.reshape(256, K_IN),
                                       valid.reshape(256, K_IN)), w0)
    p_dense = sched.jaxpr_primitives(
        lambda q: ev.insert(q, tgt, t, wa, wg, valid),
        ev.make_queue(256, 64))
    assert "sort" not in p_generic and "sort" not in p_grouped
    emit("event_wheel/jaxpr", 0.0,
         f"dense_has_sort={'sort' in p_dense};wheel_generic_sort_free=True;"
         f"wheel_grouped_sort_free=True")

    for n in sizes:
        tgt, t, wa, wg, valid = _traffic(n, K_IN)
        deq = ev.make_queue(n, 64)
        weq = sched.make_wheel(n, spec)
        dense_ins = jax.jit(lambda eq: ev.insert(eq, tgt, t, wa, wg, valid))
        wheel_ins = jax.jit(lambda eq: sched.insert(spec, eq, tgt, t, wa, wg,
                                                    valid))
        t2 = t.reshape(n, K_IN)
        wa2, wg2 = wa.reshape(n, K_IN), wg.reshape(n, K_IN)
        va2 = valid.reshape(n, K_IN)
        wheel_grp = jax.jit(lambda eq: sched.insert_grouped(spec, eq, t2, wa2,
                                                            wg2, va2))
        _, s_d = timeit(lambda: dense_ins(deq), repeats=repeats)
        _, s_w = timeit(lambda: wheel_ins(weq), repeats=repeats)
        _, s_g = timeit(lambda: wheel_grp(weq), repeats=repeats)
        E = n * K_IN
        emit(f"event_wheel/dense_insert/n{n}", s_d * 1e6, f"E={E}")
        emit(f"event_wheel/wheel_insert/n{n}", s_w * 1e6,
             f"E={E};speedup_vs_dense={s_d / s_w:.2f}x")
        emit(f"event_wheel/wheel_insert_grouped/n{n}", s_g * 1e6,
             f"E={E};speedup_vs_dense={s_d / s_g:.2f}x")

    # equivalence spot-check at the smallest size (delivered weight sums)
    n0 = sizes[0]
    tgt, t, wa, wg, valid = _traffic(n0, K_IN, seed=7)
    d1 = ev.insert(ev.make_queue(n0, 64), tgt, t, wa, wg, valid)
    w1 = sched.insert(spec, sched.make_wheel(n0, spec), tgt, t, wa, wg, valid)
    import jax.numpy as jnp
    _, da, _, dc = ev.deliver_until(d1, jnp.full((n0,), 1e9))
    _, ba, _, bc = sched.deliver_until(w1, jnp.full((n0,), 1e9))
    ok = (np.allclose(np.asarray(da), np.asarray(ba))
          and (np.asarray(dc) == np.asarray(bc)).all()
          and int(d1.dropped) == int(w1.dropped) == 0)
    emit("event_wheel/equivalence", 0.0, f"delivered_match={ok}")
    if not ok:
        raise AssertionError("wheel/dense delivery mismatch")
    dump_json("event_wheel")


if __name__ == "__main__":
    run()

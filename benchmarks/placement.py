"""Locality-aware placement benchmark: notify bytes vs topology/placement.

The sparse transport's clock-notification channel gathers each shard's
boundary set, so its bytes track the notify frontier F
(``sharding.shard_frontier``).  This benchmark builds the optimized SPMD
round on a forced-host-device mesh across a (topology, placement) grid —
uniform-random (the documented worst case: every neuron is boundary) vs
block-structured wiring with label-shuffled ids recovered by the
contiguous-block and greedy edge-cut placement passes
(``distributed.placement``) — and reports, per cell, the measured
``exchange_notify`` / ``exchange_parcel`` bytes from the compiled HLO plus
the counted cut edges and frontier sizes.

The locality claim is *asserted*, not assumed (a regression fails this
bench, and ``scripts/check.sh``, which runs it in quick mode as the local
placement smoke):

  * placed block nets cut the notify bytes vs uniform-random by at least
    ~the measured frontier ratio (the block locality factor), and by >= 2x
    outright, while parcel bytes stay cap-sized for both;
  * greedy edge-cut <= contiguous-block cut <= identity cut on the
    shuffled net (the passes never lose locality);
  * uniform-random notify bytes are unchanged by placement (worst case
    stays worst).

Runs in a subprocess (jax device counts lock at first init):
  quick (REPRO_BENCH_QUICK=1): 2x2 mesh,  N=256,  k_in=4
  full:                        16x16 mesh, N=65536, k_in=16
"""
from __future__ import annotations

import os
import subprocess
import sys

PARCEL_CAP = 8
P_IN = 0.99          # block wiring: in-block probability of each in-edge


def run() -> None:
    """Orchestrator entry (run.py / check.sh): spawn the forced-host-device
    worker, stream its CSV through, record it for the JSON dump."""
    from benchmarks.common import dump_json, record_csv

    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                        + ("4" if quick else "256"))
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.placement", "--worker"],
        env=env, capture_output=True, text=True, cwd=root,
        timeout=(900 if quick else 7200))
    sys.stdout.write(res.stdout)
    record_csv(res.stdout)
    if res.returncode != 0:
        raise RuntimeError(f"placement worker failed:\n{res.stderr[-3000:]}")
    dump_json("placement")


def _worker() -> None:
    import jax
    import numpy as np

    jax.config.update("jax_enable_x64", True)

    from benchmarks.common import emit
    from repro.core import network, topology
    from repro.core.cell import CellModel
    from repro.core import morphology
    from repro.distributed import placement as plc
    from repro.distributed.exchange import ExchangeSpec
    from repro.distributed.fap_spmd import PaperNeuroSpec, build_fap_round
    from repro.launch.hlo_analysis import collective_channel_bytes
    from repro.launch.mesh import make_mesh_compat

    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    shape = (2, 2) if quick else (16, 16)
    n = 256 if quick else 65536
    k_in = 4 if quick else 16
    mesh = make_mesh_compat(shape, ("data", "model"))
    n_shards = int(np.prod(shape))
    model = CellModel(morphology.soma_only())

    def channel_bytes(net):
        spec = PaperNeuroSpec(n_neurons=int(net.n), k_in=k_in, ev_cap=8,
                              t_end=100.0)
        fn, args, sh = build_fap_round(
            model, spec, mesh, optimized=True, transport="sparse",
            exchange=ExchangeSpec(parcel_cap=PARCEL_CAP), net=net)
        txt = jax.jit(fn, in_shardings=sh).lower(*args).compile().as_text()
        return collective_channel_bytes(txt)

    net_u = network.make_network(n, k_in=k_in, seed=0)
    net_b = network.make_network(
        n, k_in=k_in, seed=0,
        topology=topology.TopologyConfig("block", n_blocks=n_shards,
                                         p_in=P_IN))
    # scatter the block net's labels: placement must *recover* locality,
    # not inherit it from the generator's already-contiguous ids
    shuffle = np.random.default_rng(1).permutation(n)
    net_s = plc.place_network(
        net_b, plc.from_order(shuffle, n_shards, net_b, "shuffle"))

    cells = [("uniform", net_u, "identity"),
             ("block_shuffled", net_s, "identity"),
             ("block_shuffled", net_s, "block"),
             ("block_shuffled", net_s, "greedy")]
    bytes_of, stats_of, cut_of = {}, {}, {}
    for topo_name, net, method in cells:
        pl = plc.compute_placement(net, n_shards, method=method)
        placed = plc.place_network(net, pl)
        ch = channel_bytes(placed)
        st = plc.frontier_stats(net, n_shards, pl)
        key = (topo_name, method)
        bytes_of[key], stats_of[key], cut_of[key] = ch, st, pl.cut
        emit(f"placement/bytes/{topo_name}/{method}", 0.0,
             f"notify={ch['exchange_notify']};"
             f"parcel={ch['exchange_parcel']};F={st['F']};"
             f"cut={pl.cut};cut_frac={st['cut_frac']:.4f};"
             f"boundary_frac={st['boundary_frac']:.4f};n={n};"
             f"n_shards={n_shards}")

    # --- the locality claim, asserted --------------------------------------
    uni = bytes_of[("uniform", "identity")]
    for method in ("block", "greedy"):
        blk = bytes_of[("block_shuffled", method)]
        f_ratio = stats_of[("uniform", "identity")]["F"] / max(
            1, stats_of[("block_shuffled", method)]["F"])
        b_ratio = uni["exchange_notify"] / max(1, blk["exchange_notify"])
        ok = b_ratio >= max(2.0, 0.8 * f_ratio)
        emit(f"placement/locality_factor/{method}", 0.0,
             f"notify_byte_ratio={b_ratio:.2f};frontier_ratio={f_ratio:.2f};"
             f"ok={ok}")
        if not ok:
            raise AssertionError(
                f"placed block net did not cut notify bytes by the locality "
                f"factor: bytes ratio {b_ratio:.2f} vs frontier ratio "
                f"{f_ratio:.2f} ({method})")
        if blk["exchange_parcel"] != uni["exchange_parcel"]:
            raise AssertionError(
                "parcel bytes must stay cap-sized across topologies: "
                f"{blk['exchange_parcel']} vs {uni['exchange_parcel']}")
    if not (cut_of[("block_shuffled", "greedy")]
            <= cut_of[("block_shuffled", "block")]
            <= cut_of[("block_shuffled", "identity")]):
        raise AssertionError(f"placement passes lost locality: {cut_of}")
    # worst case stays worst: placement cannot manufacture locality on
    # uniform wiring (greedy may shave a sliver; the frontier stays ~N)
    pl_u = plc.compute_placement(net_u, n_shards, method="greedy")
    st_u = plc.frontier_stats(net_u, n_shards, pl_u)
    emit("placement/uniform_worst_case", 0.0,
         f"boundary_frac_placed={st_u['boundary_frac']:.4f}")
    if st_u["boundary_frac"] < 0.5:
        raise AssertionError(
            "uniform wiring should stay ~all-boundary under placement, got "
            f"boundary_frac={st_u['boundary_frac']:.4f}")


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker()
    else:
        run()

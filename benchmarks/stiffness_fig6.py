"""Paper Fig. 6: step count + runtime vs solution stiffness (continuous
current as %% of threshold), Backward Euler dt=25us vs CVODE atol=1e-3."""
from __future__ import annotations

import jax

from benchmarks.common import calibration, emit, soma_model, timeit
from repro.core import bdf
from repro.core.fixed_step import run_fixed

PCTS = [0.0, 0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 5.0]


def run(T: float = 500.0) -> None:
    model = soma_model()
    i_th = calibration()["i_threshold"]
    n_fixed = int(T / 0.025)

    for pct in PCTS:
        iinj = pct * i_th
        y0 = model.init_state()
        (_, ns, _), secs_e = timeit(
            lambda: run_fixed(model, y0, T, iinj, method="cnexp", dt=0.025))
        opts = bdf.BDFOptions(atol=1e-3)
        adv = jax.jit(lambda st: bdf.advance_to(
            model, st, T, iinj, opts, max_steps=500000))

        def bdf_run():
            st = bdf.reinit(model, 0.0, model.init_state(), iinj, opts)
            return adv(st)

        st, secs_b = timeit(bdf_run)
        nst = int(st.nst)
        emit(f"fig6/pct{pct:g}", secs_b * 1e6,
             f"euler_steps={n_fixed};cvode_steps={nst};"
             f"step_ratio={n_fixed/max(nst,1):.1f}x;"
             f"runtime_ratio={secs_e/max(secs_b,1e-9):.2f}x;"
             f"failed={bool(st.failed)}")


if __name__ == "__main__":
    run()

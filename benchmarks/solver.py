"""Solver-grade stepping: reuse-don't-rebuild Newton + NDF error constants.

Three axes, each emitting CSV records and (the first two) asserting the
PR's acceptance thresholds:

  solver/scalar_*       fig6-style stiff single-soma advance at the burst
                        drive.  Asserts (a) the default ``jac_policy=
                        "reuse"`` performs < 0.5 setups per Newton
                        iteration while ``"iteration"`` performs exactly
                        1.0, and (b) ``method="ndf"`` accepts >= 10%
                        fewer steps than BDF at equal tolerance with the
                        spike train inside the accuracy envelope (same
                        spike count, < 0.25 ms phase shift vs a 1 us
                        cnexp reference).
  solver/newton_round_* per-Newton-round linear-algebra wall time on the
                        branched tree (vmapped batch, the execution
                        models' per-round shape): the legacy fused
                        assemble+factor+solve every iteration vs the
                        reuse policy's amortized ratio*setup +
                        factored-solve at the ratio measured on the
                        stiff axis.  Asserts (c) >= 1.3x on CPU.
  solver/network_*      fig9-style FAP vardt burst-regime network run
                        reporting end-to-end wall time and the solver
                        telemetry now carried by ``RunResult.solver``
                        (soft: reported, not asserted — end-to-end CPU
                        wall time is dominated by rhs evaluations and
                        per-attempt step-control work shared by both
                        policies).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import (branched_model, calibration, emit, regime_iinj,
                               soma_model, timeit)
from repro.core import bdf

QUICK = os.environ.get("REPRO_BENCH_QUICK") == "1"


def _best_of(fn, trials: int = 5, reps: int = 50):
    jax.block_until_ready(fn())                    # compile + warm
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def _spike_times(ts, vs, thr=-20.0):
    out = []
    for i in range(1, len(ts)):
        if vs[i - 1] <= thr < vs[i]:
            f = (thr - vs[i - 1]) / (vs[i] - vs[i - 1])
            out.append(ts[i - 1] + f * (ts[i] - ts[i - 1]))
    return np.array(out)


def _trace(model, iinj, T, opts):
    """Accepted-step (t, v_soma) trace + final state, compile excluded."""
    st0 = bdf.reinit(model, 0.0, model.init_state(), iinj, opts)
    stepf = jax.jit(lambda s: bdf.step(model, s, T, iinj, opts))
    stepf(st0)
    t0 = time.time()
    st = st0
    ts, vs = [0.0], [float(st.zn[0][model.idx_vsoma])]
    while float(st.t) < T and not bool(st.failed):
        st = stepf(st)
        ts.append(float(st.t))
        vs.append(float(st.zn[0][model.idx_vsoma]))
    return np.array(ts), np.array(vs), st, time.time() - t0


def scalar_axis(T: float):
    """Stiff single-soma advance at the burst drive: counters + accuracy."""
    from repro.core.fixed_step import run_fixed

    model = soma_model()
    iinj = calibration()["i_burst"]

    (_, ns_ref, tr), _ = timeit(lambda: run_fixed(
        model, model.init_state(), T, iinj, method="cnexp", dt=0.001,
        record_every=1))
    s_ref = _spike_times(np.arange(1, ns_ref + 1) * 0.001, np.asarray(tr))

    out = {}
    for name, opts in [
            ("iteration", bdf.BDFOptions(atol=1e-3, jac_policy="iteration")),
            ("reuse", bdf.BDFOptions(atol=1e-3)),
            ("ndf", bdf.BDFOptions(atol=1e-3, method="ndf"))]:
        ts, vs, st, secs = _trace(model, iinj, T, opts)
        s = _spike_times(ts, vs)
        n = min(len(s), len(s_ref))
        shift = float(np.abs(s[:n] - s_ref[:n]).max()) if n else float("nan")
        rec = {"nst": int(st.nst), "nni": int(st.nni),
               "nsetups": int(st.nsetups),
               "ratio": int(st.nsetups) / max(int(st.nni), 1),
               "spikes": len(s), "shift": shift}
        out[name] = rec
        emit(f"solver/scalar_{name}", secs * 1e6,
             f"nst={rec['nst']};nni={rec['nni']};nsetups={rec['nsetups']};"
             f"setup_ratio={rec['ratio']:.3f};spikes={len(s)}/{len(s_ref)};"
             f"max_phase_shift_ms={shift:.4f};failed={bool(st.failed)}")

    # (a) the freshness policy actually reuses factors; legacy rebuilds
    # every iteration by construction
    assert out["iteration"]["ratio"] == 1.0, out["iteration"]
    assert out["reuse"]["ratio"] < 0.5, out["reuse"]
    # (b) NDF takes >= 10% fewer accepted steps at equal tolerance and
    # stays inside the accuracy envelope
    red = 1.0 - out["ndf"]["nst"] / max(out["iteration"]["nst"], 1)
    assert red >= 0.10, (red, out)
    assert out["ndf"]["spikes"] == len(s_ref), out["ndf"]
    assert out["ndf"]["shift"] < 0.25, out["ndf"]
    emit("solver/scalar_ndf_step_reduction", 0.0,
         f"reduction={red:.3f};threshold=0.10")
    return out["reuse"]["ratio"]


def newton_round_axis(ratio: float):
    """Per-Newton-round linear-algebra cost, vmapped over the execution
    models' per-round batch shape.  ``ratio`` is the measured setups-per-
    iteration of the reuse policy on the stiff axis."""
    model = branched_model()
    N = 16 if QUICK else 64
    rng = np.random.default_rng(0)
    y0 = np.asarray(model.init_state())
    import jax.numpy as jnp
    Y = jnp.asarray(y0[None, :] + 0.01 * rng.standard_normal((N, model.n_state)))
    gamma = jnp.asarray(rng.uniform(0.001, 0.05, N))
    B = jnp.asarray(rng.standard_normal((N, model.n_state)))

    setup = jax.jit(jax.vmap(lambda y, g: model.newton_setup(y, g, mode="schur")))
    solve = jax.jit(jax.vmap(lambda f, b: model.newton_solve(f, b, mode="schur")))
    fused = jax.jit(jax.vmap(
        lambda y, g, b: model.solve_newton_mat(y, g, b, mode="schur")))

    F = jax.block_until_ready(setup(Y, gamma))
    t_setup = _best_of(lambda: setup(Y, gamma))
    t_solve = _best_of(lambda: solve(F, B))
    t_fused = _best_of(lambda: fused(Y, gamma, B))

    legacy = t_fused                                # one rebuild per round
    amortized = ratio * t_setup + t_solve           # reuse per round
    speed = legacy / amortized
    emit("solver/newton_round_legacy", t_fused * 1e6,
         f"n={N};C={model.C};mode=schur")
    emit("solver/newton_round_reuse", amortized * 1e6,
         f"n={N};C={model.C};setup_us={t_setup*1e6:.1f};"
         f"solve_us={t_solve*1e6:.1f};setup_ratio={ratio:.3f};"
         f"speedup_vs_legacy={speed:.2f}x")
    # (c) the reuse policy's per-round Newton linear algebra beats the
    # legacy per-iteration rebuild by >= 1.3x on CPU
    assert speed >= 1.3, (speed, t_setup, t_solve, t_fused, ratio)


def network_axis(T: float):
    """fig9-style burst-regime network: end-to-end wall + solver telemetry."""
    from repro.core import exec_fap, network

    model = soma_model()
    n = 64
    net = network.make_network(n, k_in=16, seed=1)
    iinj = regime_iinj(n, "burst", seed=n)
    for name, opts in [
            ("iteration", bdf.BDFOptions(atol=1e-3, jac_policy="iteration")),
            ("ndf_reuse", bdf.BDFOptions(atol=1e-3, method="ndf"))]:
        runner = exec_fap.make_fap_vardt_runner(model, net, iinj, T, opts=opts)
        jax.block_until_ready(runner())             # compile + run
        t0 = time.time()
        out = jax.block_until_ready(runner())
        secs = time.time() - t0
        res = out if not isinstance(out, tuple) else out[0]
        sv = res.solver
        emit(f"solver/network_burst_{name}", secs * 1e6 / max(T, 1e-9),
             f"t_bio_ms={T};wall_s={secs:.3f};nst={int(sv['nst'])};"
             f"nni={int(sv['nni'])};nsetups={int(sv['nsetups'])};"
             f"setup_ratio={int(sv['nsetups'])/max(int(sv['nni']),1):.3f};"
             f"spikes={int(res.rec.count.sum())};failed={bool(res.failed)}")


def run() -> None:
    from benchmarks.common import dump_json
    T = 25.0 if QUICK else 100.0
    ratio = scalar_axis(T)
    newton_round_axis(ratio)
    network_axis(10.0 if QUICK else 25.0)
    dump_json("solver")


if __name__ == "__main__":
    run()

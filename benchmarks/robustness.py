"""Preemption-tolerance overhead + kill/resume identity smoke (ISSUE 9).

Two axes:

  robustness/ckpt_*      round-boundary SimCarry checkpoint cost on the
                         single-host FAP vardt runner: save + restore
                         wall time for one snapshot, and the end-to-end
                         overhead of driving the run host-stepped with
                         checkpoint_every=k vs the uninterrupted
                         host-stepped run (the fair baseline — the
                         jitted while_loop path has no save hook).
  robustness/resume_*    the CI acceptance smoke: kill the checkpointed
                         run via SimulatedFailure mid-way, resume from
                         the latest snapshot, and ASSERT the spike train
                         is bit-identical to the uninterrupted run and
                         the poisoned-lane watchdog rolls back to an
                         identical completion (detected, never silent).

Quick mode (REPRO_BENCH_QUICK=1) trims the network and horizon so the
whole suite is a few host-stepped runs — cheap enough for check.sh.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import dump_json, emit, soma_model
from repro.checkpoint import FaultPlan, SimulatedFailure
from repro.core import exec_common as xc
from repro.core import exec_fap, network

QUICK = os.environ.get("REPRO_BENCH_QUICK") == "1"


def run():
    n = 32 if QUICK else 128
    t_end = 10.0 if QUICK else 25.0
    model = soma_model()
    net = network.make_network(n, k_in=4, seed=3)
    rng = np.random.default_rng(1)
    iinj = 0.16 + 0.004 * rng.standard_normal(n)
    runner = exec_fap.make_fap_vardt_runner(model, net, iinj, t_end)

    # --- uninterrupted host-stepped baseline ------------------------------
    runner(watchdog=True)                      # warm: compile the round
    t0 = time.perf_counter()
    res0, rounds0 = runner(watchdog=True)
    base_s = time.perf_counter() - t0
    rounds0 = int(rounds0)
    assert not bool(res0.failed) and int(res0.dropped) == 0
    times0 = np.asarray(res0.rec.times)
    count0 = np.asarray(res0.rec.count)

    # --- one snapshot save + restore cost --------------------------------
    sc = runner.pack(runner.init_carry())
    d = tempfile.mkdtemp()
    try:
        t0 = time.perf_counter()
        xc.save_sim_checkpoint(d, 1, sc)
        save_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        sc2, _, skipped = xc.restore_sim_checkpoint(d, 1, sc)
        restore_s = time.perf_counter() - t0
        assert skipped == []
        nbytes = sum(np.asarray(x).nbytes
                     for x in __import__("jax").tree_util.tree_leaves(sc))
        emit("robustness/ckpt_save", save_s * 1e6,
             f"n={n} simcarry={nbytes / 1e6:.2f}MB")
        emit("robustness/ckpt_restore", restore_s * 1e6,
             f"n={n} crc32-verified")
    finally:
        shutil.rmtree(d, ignore_errors=True)

    # --- end-to-end overhead of checkpoint_every=k ------------------------
    every = max(2, rounds0 // 8)
    d = tempfile.mkdtemp()
    try:
        t0 = time.perf_counter()
        res1, rounds1 = runner(checkpoint_every=every, ckpt_dir=d)
        ck_s = time.perf_counter() - t0
        saved = res1.health["checkpoints_saved"]
        assert saved >= 2
        emit("robustness/ckpt_run_overhead", (ck_s - base_s) / max(1, saved)
             * 1e6, f"per-save amortized, every={every} saves={saved} "
             f"baseline={base_s:.2f}s")
        # --- kill mid-run, resume: bit-identical (the CI acceptance) -----
        try:
            runner(checkpoint_every=every, ckpt_dir=d,
                   fault=FaultPlan(fail_at_round=max(1, rounds0 // 2)))
            raise AssertionError("SimulatedFailure did not fire")
        except SimulatedFailure:
            pass
        t0 = time.perf_counter()
        res2, rounds2 = runner(checkpoint_every=every, ckpt_dir=d,
                               resume=True)
        resume_s = time.perf_counter() - t0
        assert np.array_equal(times0, np.asarray(res2.rec.times))
        assert np.array_equal(count0, np.asarray(res2.rec.count))
        assert int(rounds2) == rounds0
        emit("robustness/resume_kill", resume_s * 1e6,
             f"bit-identical from round {res2.health['resumed_from']}"
             f"/{rounds0}")
    finally:
        shutil.rmtree(d, ignore_errors=True)

    # --- watchdog: poisoned lane -> rollback -> identical completion ------
    d = tempfile.mkdtemp()
    try:
        t0 = time.perf_counter()
        res3, _ = runner(checkpoint_every=every, ckpt_dir=d,
                         fault=FaultPlan(poison_at_round=max(1, rounds0 // 3),
                                         poison_lane=1))
        poison_s = time.perf_counter() - t0
        assert res3.health["nonfinite_rounds"] >= 1
        assert res3.health["rollbacks"] >= 1
        assert not bool(res3.failed)
        assert np.array_equal(times0, np.asarray(res3.rec.times))
        emit("robustness/resume_poison_rollback", poison_s * 1e6,
             f"rollbacks={res3.health['rollbacks']} bit-identical")
    finally:
        shutil.rmtree(d, ignore_errors=True)

    dump_json("robustness")


if __name__ == "__main__":
    run()

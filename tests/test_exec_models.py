"""Cross-validation of the four execution models (the paper's Fig. 1):
identical physics -> BSP-fixed and FAP-fixed agree EXACTLY; the vardt
models agree with each other and with fixed-step to discretisation error."""
import jax
import numpy as np
import pytest

from repro.core import bdf, morphology, network
from repro.core import exec_bsp, exec_fap
from repro.core.cell import CellModel

T_END = 30.0


@pytest.fixture(scope="module")
def setup():
    model = CellModel(morphology.soma_only())
    net = network.make_network(16, k_in=6, seed=3)
    rng = np.random.default_rng(1)
    iinj = 0.16 + 0.004 * rng.standard_normal(16)
    return model, net, iinj


def _trains(res):
    ts = np.asarray(res.rec.times)
    c = np.asarray(res.rec.count)
    return [np.sort(ts[i][: c[i]]) for i in range(len(c))]


@pytest.fixture(scope="module")
def results(setup):
    model, net, iinj = setup
    r_bspf = exec_bsp.run_bsp_fixed(model, net, iinj, T_END, method="cnexp")
    r_fapf = exec_fap.run_fap_fixed(model, net, iinj, T_END, method="cnexp")
    r_bspv = exec_bsp.run_bsp_vardt(model, net, iinj, T_END)
    r_fapv = exec_fap.run_fap_vardt(model, net, iinj, T_END)
    return r_bspf, r_fapf, r_bspv, r_fapv


def test_no_failures_no_drops(results):
    for r in results:
        assert int(r.dropped) == 0
        assert not bool(r.failed)
        assert int(r.rec.overflow) == 0


def test_bsp_fixed_equals_fap_fixed_exactly(results):
    r_bspf, r_fapf = results[0], results[1]
    t1, t2 = _trains(r_bspf), _trains(r_fapf)
    for a, b in zip(t1, t2):
        np.testing.assert_allclose(a, b, atol=1e-9)
    assert int(r_bspf.n_events) == int(r_fapf.n_events)


def test_vardt_models_agree(results):
    r_bspv, r_fapv = results[2], results[3]
    t3, t4 = _trains(r_bspv), _trains(r_fapv)
    # different step sequences -> tiny numeric divergence; neurons at the
    # spike/no-spike boundary may flip (chaotic near-threshold dynamics).
    mismatched = sum(len(a) != len(b) for a, b in zip(t3, t4))
    assert mismatched <= 2
    for a, b in zip(t3, t4):
        if len(a) == len(b) and len(a):
            assert np.abs(a - b).max() < 0.25       # ms
    tot3 = sum(len(a) for a in t3)
    tot4 = sum(len(b) for b in t4)
    assert abs(tot3 - tot4) <= max(2, 0.05 * tot3)


def test_vardt_matches_fixed_physics(results):
    t1, t4 = _trains(results[0]), _trains(results[3])
    n_sp_fixed = sum(len(a) for a in t1)
    n_sp_vardt = sum(len(a) for a in t4)
    assert n_sp_fixed > 10                          # network actually active
    assert abs(n_sp_fixed - n_sp_vardt) <= max(2, 0.1 * n_sp_fixed)


def test_event_grouping_reduces_resets(setup):
    """Paper §4.2: EG variants trade delivery precision for fewer IVP
    resets; spike counts stay comparable."""
    model, net, iinj = setup
    r_precise = exec_fap.run_fap_vardt(model, net, iinj, T_END, eg_window=0.0)
    r_eg = exec_fap.run_fap_vardt(model, net, iinj, T_END, eg_window=0.025)
    assert int(r_eg.n_resets) <= int(r_precise.n_resets)
    n1 = int(r_precise.rec.count.sum())
    n2 = int(r_eg.rec.count.sum())
    assert abs(n1 - n2) <= max(2, 0.15 * n1)


def test_fap_vardt_fewer_steps_than_bsp_vardt_quiet(setup):
    """The paper's core claim: without the BSP window clamp, quiet neurons
    take far fewer (longer) steps."""
    model, net, _ = setup
    iinj = np.zeros(net.n)                          # fully quiet network
    r_bspv = exec_bsp.run_bsp_vardt(model, net, iinj, T_END)
    r_fapv = exec_fap.run_fap_vardt(model, net, iinj, T_END)
    assert int(r_fapv.n_steps) < int(r_bspv.n_steps) / 2
    assert int(r_fapv.rec.count.sum()) == 0 == int(r_bspv.rec.count.sum())


def test_scheduler_k_select_equivalence(setup):
    """Restricting each round to the K earliest neurons (the explicit
    scheduler) must not change the computed physics: same spike counts up
    to near-threshold flips, matched spikes within step tolerance (the
    horizon sequence differs, so step sizes and rounding differ)."""
    model, net, iinj = setup
    r_all = exec_fap.run_fap_vardt(model, net, iinj, 15.0)
    r_k = exec_fap.run_fap_vardt(model, net, iinj, 15.0, k_select=4)
    ta, tk = _trains(r_all), _trains(r_k)
    mismatched = sum(len(a) != len(b) for a, b in zip(ta, tk))
    assert mismatched <= 1
    for a, b in zip(ta, tk):
        if len(a) == len(b) and len(a):
            assert np.abs(a - b).max() < 0.25

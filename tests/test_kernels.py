"""Per-kernel allclose sweeps vs the pure-jnp oracles (shapes x dtypes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import morphology
from repro.core.hines import hines_assemble

# ---------------------------------------------------------------- hines ----
from repro.kernels.hines.ops import hines_solve_batched
from repro.kernels.hines.ref import dense_solve_ref, hines_solve_ref

HINES_CASES = [
    ("soma", morphology.soma_only(), 1),
    ("bs", morphology.ball_and_stick(5), 33),
    ("br2", morphology.branched_tree(2, 2), 256),
    ("br3", morphology.branched_tree(3, 2), 300),
]


@pytest.mark.parametrize("name,m,N", HINES_CASES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float64, 1e-12), (jnp.float32, 2e-4)])
def test_hines_kernel_sweep(name, m, N, dtype, tol):
    key = jax.random.PRNGKey(len(name) + N)
    parent = jnp.asarray(m.parent)
    gax = jnp.asarray(m.g_axial, dtype)
    de = jax.random.uniform(key, (N, m.n_comp), dtype) + 0.5
    d = jax.vmap(lambda x: hines_assemble(parent, gax, x))(de).T
    b = jax.random.normal(key, (m.n_comp, N), dtype)
    x_k = hines_solve_batched(parent, gax, d, b, block_n=128)
    x_r = hines_solve_ref(parent, gax.astype(jnp.float64),
                          d.astype(jnp.float64), b.astype(jnp.float64))
    np.testing.assert_allclose(np.asarray(x_k, np.float64), np.asarray(x_r),
                               rtol=tol, atol=tol)


def test_hines_kernel_vs_dense_oracle():
    m = morphology.branched_tree(2, 3)
    key = jax.random.PRNGKey(0)
    parent, gax = jnp.asarray(m.parent), jnp.asarray(m.g_axial)
    de = jax.random.uniform(key, (64, m.n_comp)) + 0.5
    d = jax.vmap(lambda x: hines_assemble(parent, gax, x))(de).T
    b = jax.random.normal(key, (m.n_comp, 64))
    np.testing.assert_allclose(
        np.asarray(hines_solve_batched(parent, gax, d, b)),
        np.asarray(dense_solve_ref(parent, gax, d, b)), rtol=1e-9, atol=1e-11)


@pytest.mark.parametrize("name,m,N", HINES_CASES)
def test_hines_factor_solve_split_kernels(name, m, N):
    """The setup/solve split (ISSUE 7): the factor kernel's eliminated
    diagonal matches the reference, and factor + factored-solve composes
    to the fused solve bitwise — the property the Newton factor cache
    relies on to leave trajectories untouched."""
    from repro.kernels.hines.ops import (hines_factor_batched,
                                         hines_solve_factored_batched)
    from repro.kernels.hines.ref import (hines_factor_ref,
                                         hines_solve_factored_ref)
    key = jax.random.PRNGKey(len(name) + N)
    parent = jnp.asarray(m.parent)
    gax = jnp.asarray(m.g_axial)
    de = jax.random.uniform(key, (N, m.n_comp)) + 0.5
    d = jax.vmap(lambda x: hines_assemble(parent, gax, x))(de).T
    b = jax.random.normal(key, (m.n_comp, N))
    d_elim = hines_factor_batched(parent, gax, d, block_n=128)
    np.testing.assert_allclose(
        np.asarray(d_elim), np.asarray(hines_factor_ref(parent, gax, d)),
        rtol=1e-12, atol=1e-12)
    x_split = hines_solve_factored_batched(parent, gax, d_elim, b,
                                           block_n=128)
    np.testing.assert_allclose(
        np.asarray(x_split),
        np.asarray(hines_solve_factored_ref(parent, gax, d_elim, b)),
        rtol=1e-12, atol=1e-12)
    assert np.array_equal(np.asarray(x_split),
                          np.asarray(hines_solve_batched(parent, gax, d, b,
                                                         block_n=128)))


# --------------------------------------------------------------- hh_rhs ----
from repro.kernels.hh_rhs.ops import hh_rhs_batched
from repro.kernels.hh_rhs.ref import hh_rhs_ref


@pytest.mark.parametrize("N,C", [(1, 1), (7, 13), (256, 29), (300, 8)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float64, 1e-12), (jnp.float32, 1e-4)])
def test_hh_rhs_kernel_sweep(N, C, dtype, tol):
    key = jax.random.PRNGKey(N * C)
    area = jax.random.uniform(key, (C,), dtype) * 1000 + 50
    v = jax.random.uniform(key, (N, C), dtype) * 120 - 90
    gates = [jax.random.uniform(jax.random.fold_in(key, i), (N, C), dtype)
             for i in range(3)]
    outs_k = hh_rhs_batched(area, v, *gates, block_n=128)
    outs_r = hh_rhs_ref(area.astype(jnp.float64), v.astype(jnp.float64),
                        *[g.astype(jnp.float64) for g in gates])
    for a, b in zip(outs_k, outs_r):
        np.testing.assert_allclose(np.asarray(a, np.float64), np.asarray(b),
                                   rtol=tol, atol=tol * 10)


# ------------------------------------------------------------ attention ----
from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import attention_ref

ATTN_CASES = [
    (1, 4, 4, 64, 64, 32, True),
    (2, 8, 2, 128, 128, 64, True),      # GQA
    (1, 4, 4, 1, 128, 64, True),        # decode
    (2, 6, 3, 37, 100, 16, True),       # ragged + GQA
    (1, 4, 2, 64, 64, 32, False),       # bidirectional
]


@pytest.mark.parametrize("B,H,Hkv,Sq,Skv,D,causal", ATTN_CASES)
def test_flash_attention_sweep(B, H, Hkv, Sq, Skv, D, causal):
    ks = jax.random.split(jax.random.PRNGKey(B * Sq + Skv), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), jnp.float32)
    o_k = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    o_r = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_block_size_invariance():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 96, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 96, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 96, 32), jnp.float32)
    o1 = flash_attention(q, k, v, bq=32, bk=32)
    o2 = flash_attention(q, k, v, bq=96, bk=48)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)

"""Event queue: vectorised insert/deliver invariants (deterministic).

The hypothesis-based property sweeps live in test_property_events.py so
this module collects even when the optional dev dependency is absent."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import events as ev


def test_insert_and_deliver_roundtrip():
    eq = ev.make_queue(4, 8)
    tgt = jnp.array([0, 0, 1, 3], jnp.int32)
    t = jnp.array([1.0, 0.5, 2.0, 0.1])
    wa = jnp.array([0.1, 0.2, 0.3, 0.4])
    wg = jnp.zeros(4)
    eq = ev.insert(eq, tgt, t, wa, wg, jnp.ones(4, bool))
    assert int(eq.dropped) == 0
    nt = ev.next_time(eq)
    np.testing.assert_allclose(np.asarray(nt), [0.5, 2.0, np.inf, 0.1])
    # deliver everything due by t=1.0 for neuron 0 only
    eq, da, dg, cnt = ev.deliver_until(eq, jnp.array([1.0, 0.0, 0.0, 0.0]))
    assert float(da[0]) == pytest.approx(0.3)       # both neuron-0 events
    assert int(cnt[0]) == 2 and int(cnt[1]) == 0
    assert float(ev.next_time(eq)[0]) == np.inf


def test_overflow_detected_not_silent():
    eq = ev.make_queue(2, 2)
    tgt = jnp.zeros(5, jnp.int32)
    t = jnp.arange(5) * 1.0
    eq = ev.insert(eq, tgt, t, t, t, jnp.ones(5, bool))
    assert int(eq.dropped) == 3


def test_invalid_events_ignored():
    eq = ev.make_queue(2, 4)
    tgt = jnp.array([0, 1], jnp.int32)
    eq = ev.insert(eq, tgt, jnp.ones(2), jnp.ones(2), jnp.zeros(2),
                   jnp.array([True, False]))
    assert np.isinf(np.asarray(eq.t)[1]).all()
    assert not np.isinf(np.asarray(eq.t)[0]).all()


def test_spike_record():
    rec = ev.make_spike_record(3, capacity=2)
    rec = ev.record_spikes(rec, jnp.arange(3), jnp.array([1.0, 2.0, 3.0]),
                           jnp.array([True, False, True]))
    assert list(np.asarray(rec.count)) == [1, 0, 1]
    rec = ev.record_spikes(rec, jnp.arange(3), jnp.array([4.0, 5.0, 6.0]),
                           jnp.array([True, True, True]))
    rec = ev.record_spikes(rec, jnp.arange(3), jnp.array([7.0, 8.0, 9.0]),
                           jnp.array([True, False, False]))
    assert int(rec.overflow) == 1                   # neuron 0 hit capacity
    assert list(np.asarray(rec.count)) == [2, 1, 2]

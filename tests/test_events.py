"""Event queue: vectorised insert/deliver invariants (+ hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import events as ev


def test_insert_and_deliver_roundtrip():
    eq = ev.make_queue(4, 8)
    tgt = jnp.array([0, 0, 1, 3], jnp.int32)
    t = jnp.array([1.0, 0.5, 2.0, 0.1])
    wa = jnp.array([0.1, 0.2, 0.3, 0.4])
    wg = jnp.zeros(4)
    eq = ev.insert(eq, tgt, t, wa, wg, jnp.ones(4, bool))
    assert int(eq.dropped) == 0
    nt = ev.next_time(eq)
    np.testing.assert_allclose(np.asarray(nt), [0.5, 2.0, np.inf, 0.1])
    # deliver everything due by t=1.0 for neuron 0 only
    eq, da, dg, cnt = ev.deliver_until(eq, jnp.array([1.0, 0.0, 0.0, 0.0]))
    assert float(da[0]) == pytest.approx(0.3)       # both neuron-0 events
    assert int(cnt[0]) == 2 and int(cnt[1]) == 0
    assert float(ev.next_time(eq)[0]) == np.inf


def test_overflow_detected_not_silent():
    eq = ev.make_queue(2, 2)
    tgt = jnp.zeros(5, jnp.int32)
    t = jnp.arange(5) * 1.0
    eq = ev.insert(eq, tgt, t, t, t, jnp.ones(5, bool))
    assert int(eq.dropped) == 3


def test_invalid_events_ignored():
    eq = ev.make_queue(2, 4)
    tgt = jnp.array([0, 1], jnp.int32)
    eq = ev.insert(eq, tgt, jnp.ones(2), jnp.ones(2), jnp.zeros(2),
                   jnp.array([True, False]))
    assert np.isinf(np.asarray(eq.t)[1]).all()
    assert not np.isinf(np.asarray(eq.t)[0]).all()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7),
                          st.floats(0.01, 100.0, allow_nan=False)),
                min_size=1, max_size=32))
def test_no_event_lost_property(evs):
    """Hypothesis: every valid inserted event is delivered exactly once,
    with its exact weight, provided capacity suffices."""
    n, cap = 8, 64
    eq = ev.make_queue(n, cap)
    tgt = jnp.array([e[0] for e in evs], jnp.int32)
    t = jnp.array([e[1] for e in evs])
    wa = jnp.ones(len(evs))
    eq = ev.insert(eq, tgt, t, wa, jnp.zeros(len(evs)), jnp.ones(len(evs), bool))
    assert int(eq.dropped) == 0
    eq, da, _, cnt = ev.deliver_until(eq, jnp.full((n,), 1e9))
    per_target = np.zeros(n)
    for tg, _ in evs:
        per_target[tg] += 1.0
    np.testing.assert_allclose(np.asarray(da), per_target)
    assert int(cnt.sum()) == len(evs)
    assert np.isinf(np.asarray(eq.t)).all()         # queue fully drained


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_partial_delivery_order_property(seed):
    """Delivering up to t only pops events <= t; later events remain."""
    rng = np.random.default_rng(seed)
    n, cap, E = 4, 32, 20
    eq = ev.make_queue(n, cap)
    tgt = jnp.asarray(rng.integers(0, n, E), jnp.int32)
    t = jnp.asarray(rng.uniform(0, 10, E))
    eq = ev.insert(eq, tgt, t, jnp.ones(E), jnp.zeros(E), jnp.ones(E, bool))
    cut = float(rng.uniform(0, 10))
    eq2, da, _, cnt = ev.deliver_until(eq, jnp.full((n,), cut))
    expect = np.zeros(n)
    for tg, tt in zip(np.asarray(tgt), np.asarray(t)):
        if tt <= cut:
            expect[tg] += 1
    np.testing.assert_allclose(np.asarray(da), expect)
    remaining = np.asarray(eq2.t)
    assert (remaining[np.isfinite(remaining)] > cut).all()


def test_spike_record():
    rec = ev.make_spike_record(3, capacity=2)
    rec = ev.record_spikes(rec, jnp.arange(3), jnp.array([1.0, 2.0, 3.0]),
                           jnp.array([True, False, True]))
    assert list(np.asarray(rec.count)) == [1, 0, 1]
    rec = ev.record_spikes(rec, jnp.arange(3), jnp.array([4.0, 5.0, 6.0]),
                           jnp.array([True, True, True]))
    rec = ev.record_spikes(rec, jnp.arange(3), jnp.array([7.0, 8.0, 9.0]),
                           jnp.array([True, False, False]))
    assert int(rec.overflow) == 1                   # neuron 0 hit capacity
    assert list(np.asarray(rec.count)) == [2, 1, 2]

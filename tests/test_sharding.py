"""Sharding rules: divisibility fitting, spec/tree alignment, constraint
no-op behaviour outside a sharding context."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.distributed import sharding as shd
from repro.distributed.ctx import constrain, sharding_ctx
from repro.launch.mesh import make_local_mesh
from repro.models import lm
from repro.optim import adamw_init


@pytest.fixture(scope="module")
def mesh():
    return make_local_mesh()


def _sds_params(cfg):
    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_specs_cover_and_fit(name, mesh):
    cfg = ARCHS[name]
    params = _sds_params(cfg)
    specs = shd.fit_specs(shd.param_specs(cfg, params, mesh), params, mesh)
    leaves_p = jax.tree_util.tree_leaves(params)
    leaves_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_p) == len(leaves_s)
    for p, s in zip(leaves_p, leaves_s):
        assert len(s) <= p.ndim
        for dim, entry in zip(p.shape, tuple(s)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            sz = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % sz == 0, (name, p.shape, s)


def test_fit_drops_nondivisible(mesh):
    spec = P("data", None)
    sds = jax.ShapeDtypeStruct((7, 8), jnp.float32)   # 7 not divisible
    fitted = shd._fit_one(spec, sds.shape, mesh)
    if 7 % mesh.shape["data"] == 0:                   # 1-device test mesh
        assert fitted == P("data", None)
    else:
        assert fitted == P(None, None)
    # synthetic axis-size check independent of the local mesh
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    assert shd._fit_one(P("data", "model"), (7, 32), FakeMesh()) == \
        P(None, "model")


def test_big_params_are_actually_sharded(mesh):
    """FSDP/TP must shard every large matrix (no silent replication)."""
    cfg = ARCHS["qwen2-72b"]
    params = _sds_params(cfg)
    specs = shd.fit_specs(shd.param_specs(cfg, params, mesh), params, mesh)

    def check(path, leaf, spec):
        if leaf.size >= 1_000_000:
            assert any(e is not None for e in tuple(spec)), (path, leaf.shape)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), params, specs,
        is_leaf=lambda x: isinstance(x, P))


def test_opt_specs_mirror_params(mesh):
    cfg = ARCHS["smollm-135m"].reduced()
    params = _sds_params(cfg)
    opt = jax.eval_shape(adamw_init, params)
    ospecs = shd.opt_specs(cfg, opt, mesh)
    pspecs = shd.param_specs(cfg, params, mesh)
    assert jax.tree_util.tree_structure(
        ospecs.mu, is_leaf=lambda x: isinstance(x, P)) == \
        jax.tree_util.tree_structure(
            pspecs, is_leaf=lambda x: isinstance(x, P))


def test_constrain_noop_outside_ctx():
    x = jnp.ones((4, 4))
    y = constrain(x, "residual")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_constrain_applies_inside_ctx(mesh):
    with sharding_ctx(mesh):
        @jax.jit
        def f(x):
            return constrain(x, "tokens") * 2.0

        x = jnp.ones((mesh.shape["data"] * 2, 8))
        y = f(x)
    np.testing.assert_array_equal(np.asarray(y), 2 * np.ones_like(np.asarray(y)))

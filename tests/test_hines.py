"""Hines tree solve: exactness vs dense linear algebra, across morphologies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import morphology
from repro.core.hines import dense_tree_matrix, hines_assemble, hines_solve

MORPHS = {
    "soma": morphology.soma_only(),
    "ball_and_stick": morphology.ball_and_stick(n_dend=7),
    "branched2": morphology.branched_tree(depth=2, seg_per_branch=2),
    "branched3": morphology.branched_tree(depth=3, seg_per_branch=3),
}


@pytest.mark.parametrize("name", sorted(MORPHS))
def test_hines_matches_dense(name):
    m = MORPHS[name]
    parent = jnp.asarray(m.parent)
    gax = jnp.asarray(m.g_axial)
    key = jax.random.PRNGKey(hash(name) % 2**31)
    diag_extra = jax.random.uniform(key, (m.n_comp,)) + 0.5
    b = jax.random.normal(key, (m.n_comp,))
    d = hines_assemble(parent, gax, diag_extra)
    x = hines_solve(parent, gax, d, b)
    A = dense_tree_matrix(parent, gax, diag_extra)
    x_ref = jnp.linalg.solve(A, b)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_ref),
                               rtol=1e-10, atol=1e-12)


def test_hines_order_property():
    for m in MORPHS.values():
        assert m.parent[0] == -1
        assert np.all(m.parent[1:] < np.arange(1, m.n_comp))
        assert m.g_axial[0] == 0.0
        assert np.all(m.g_axial[1:] > 0.0)


def test_random_trees_match_dense():
    rng = np.random.default_rng(0)
    for trial in range(5):
        C = int(rng.integers(2, 40))
        parent = np.full(C, -1, np.int32)
        for i in range(1, C):
            parent[i] = rng.integers(0, i)
        gax = np.concatenate([[0.0], rng.uniform(0.01, 1.0, C - 1)])
        diag_extra = rng.uniform(0.5, 2.0, C)
        b = rng.normal(size=C)
        d = hines_assemble(jnp.asarray(parent), jnp.asarray(gax),
                           jnp.asarray(diag_extra))
        x = hines_solve(jnp.asarray(parent), jnp.asarray(gax), d,
                        jnp.asarray(b))
        A = dense_tree_matrix(jnp.asarray(parent), jnp.asarray(gax),
                              jnp.asarray(diag_extra))
        x_ref = np.linalg.solve(np.asarray(A), b)
        np.testing.assert_allclose(np.asarray(x), x_ref, rtol=1e-9, atol=1e-11)

"""BDF integrator: accuracy vs fine fixed-step reference, adaptivity,
order selection, tstop semantics, IVP reset."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bdf, morphology
from repro.core.cell import CellModel
from repro.core.fixed_step import run_fixed


@pytest.fixture(scope="module")
def soma_model():
    return CellModel(morphology.soma_only())


def _spike_times(ts, vs, thr=-20.0):
    out = []
    for i in range(1, len(ts)):
        if vs[i - 1] <= thr < vs[i]:
            f = (thr - vs[i - 1]) / (vs[i] - vs[i - 1])
            out.append(ts[i - 1] + f * (ts[i] - ts[i - 1]))
    return np.array(out)


def _bdf_trace(model, iinj, T, atol=1e-3):
    opts = bdf.BDFOptions(atol=atol)
    st = bdf.reinit(model, 0.0, model.init_state(), iinj, opts)
    stepf = jax.jit(lambda s: bdf.step(model, s, T, iinj, opts))
    ts, vs = [0.0], [float(st.zn[0][0])]
    while float(st.t) < T:
        st = stepf(st)
        assert not bool(st.failed)
        ts.append(float(st.t))
        vs.append(float(st.zn[0][model.idx_vsoma]))
    return np.array(ts), np.array(vs), st


def test_spike_times_match_fine_reference(soma_model):
    T, iinj = 60.0, 0.15
    _, ns, tr = run_fixed(soma_model, soma_model.init_state(), T, iinj,
                          method="cnexp", dt=0.001, record_every=1)
    s_ref = _spike_times(np.arange(1, ns + 1) * 0.001, np.asarray(tr))
    ts, vs, st = _bdf_trace(soma_model, iinj, T)
    s_bdf = _spike_times(ts, vs)
    assert len(s_ref) == len(s_bdf) >= 3
    # paper Fig.5: vardt tracks the reference with no accumulating shift
    assert np.abs(s_ref - s_bdf).max() < 0.1  # ms
    # ~50x fewer steps than the 1us reference at matched accuracy
    assert int(st.nst) < ns / 20


def test_quiet_neuron_giant_steps(soma_model):
    """Paper Fig.6: subthreshold input -> hundreds-fold fewer steps."""
    opts = bdf.BDFOptions(atol=1e-3)
    st = bdf.reinit(soma_model, 0.0, soma_model.init_state(), 0.0, opts)
    st = jax.jit(lambda s: bdf.advance_to(soma_model, s, 1000.0, 0.0, opts))(st)
    assert not bool(st.failed)
    assert float(st.t) >= 1000.0 - 1e-6
    assert int(st.nst) < 200                       # vs 40,000 fixed steps
    assert float(st.h) > 5.0                       # step grew to many ms


def test_order_adapts_above_one(soma_model):
    opts = bdf.BDFOptions(atol=1e-3)
    st = bdf.reinit(soma_model, 0.0, soma_model.init_state(), 0.0, opts)
    stepper = jax.jit(lambda s: bdf.step(soma_model, s, 50.0, 0.0, opts))
    q_max = 1
    for _ in range(200):
        st = stepper(st)
        q_max = max(q_max, int(st.q))
        if float(st.t) >= 50.0 - 1e-9:
            break
    # variable-ORDER engaged (the final tstop-clamped step may legitimately
    # drop back to 1, so the peak order is the robust observable)
    assert q_max >= 2


def test_tstop_never_overstepped(soma_model):
    opts = bdf.BDFOptions(atol=1e-3)
    st = bdf.reinit(soma_model, 0.0, soma_model.init_state(), 0.1, opts)
    for t_limit in [0.5, 0.8, 1.7, 5.0]:
        st = jax.jit(lambda s, tl: bdf.advance_to(soma_model, s, tl, 0.1,
                                                  opts))(st, t_limit)
        assert float(st.t) <= t_limit + 1e-9
        assert abs(float(st.t) - t_limit) < 1e-6   # lands ON the limit


def test_event_reset_semantics(soma_model):
    opts = bdf.BDFOptions(atol=1e-3)
    st = bdf.reinit(soma_model, 0.0, soma_model.init_state(), 0.0, opts)
    st = jax.jit(lambda s: bdf.advance_to(soma_model, s, 10.0, 0.0, opts))(st)
    q_before, h_before = int(st.q), float(st.h)
    st2 = bdf.deliver_event(soma_model, st, 5e-3, 0.0, 0.0, opts)
    assert int(st2.q) == 1                         # history discarded
    assert float(st2.h) < h_before                 # fresh small step
    assert int(st2.nreset) == int(st.nreset) + 1
    g = float(st2.zn[0][soma_model.idx_g_ampa])
    assert g == pytest.approx(5e-3)                # discontinuity applied
    # and voltage rises after the EPSP
    st3 = jax.jit(lambda s: bdf.advance_to(soma_model, s, float(st2.t) + 2.0,
                                           0.0, opts))(st2)
    assert float(st3.zn[0][0]) > float(st.zn[0][0])


def test_interpolate_dense_output(soma_model):
    opts = bdf.BDFOptions(atol=1e-4)
    st = bdf.reinit(soma_model, 0.0, soma_model.init_state(), 0.0, opts)
    st = jax.jit(lambda s: bdf.advance_to(soma_model, s, 5.0, 0.0, opts))(st)
    y_now = bdf.interpolate(st, st.t)
    np.testing.assert_allclose(np.asarray(y_now), np.asarray(st.zn[0]),
                               rtol=1e-8, atol=1e-10)


def test_branched_cell_integrates(soma_model):
    model = CellModel(morphology.branched_tree(depth=2, seg_per_branch=2))
    opts = bdf.BDFOptions(atol=1e-3)
    st = bdf.reinit(model, 0.0, model.init_state(), 0.3, opts)
    st = jax.jit(lambda s: bdf.advance_to(model, s, 20.0, 0.3, opts))(st)
    assert not bool(st.failed)
    assert float(st.t) >= 20.0 - 1e-6
    assert np.all(np.isfinite(np.asarray(st.zn[0])))


def test_plasticity_complex_model_fully_implicit():
    """The correlated cubic (ca, rho) pair — the case needing the paper's
    fully-implicit solver — integrates stably through an event."""
    model = CellModel(morphology.soma_only(), with_plasticity=True)
    opts = bdf.BDFOptions(atol=1e-4)
    st = bdf.reinit(model, 0.0, model.init_state(), 0.0, opts)
    st = bdf.deliver_event(model, st, 1e-3, 0.0, 0.0, opts)
    st = jax.jit(lambda s: bdf.advance_to(model, s, 50.0, 0.0, opts))(st)
    assert not bool(st.failed)
    y = np.asarray(st.zn[0])
    assert np.all(np.isfinite(y))
    rho = y[model.idx_ca + 1]
    assert 0.0 <= rho <= 1.0


def test_schur_preconditioner_matches_and_tightens(soma_model):
    """Beyond-paper: the exact-HH-block Schur Newton matrix must (a) solve
    (I - gamma J) exactly on the HH block (dense-oracle check) and (b) give
    the same trajectory with no more Newton iterations than NEURON's
    dropped-coupling default."""
    import jax.numpy as jnp
    model = soma_model
    y0 = model.init_state()
    # (a) exactness against the dense (I - gamma J) on the HH sub-block
    gamma = 0.05
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(size=model.n_state))
    x = model.solve_newton_mat(y0, gamma, b, mode="schur")
    J = model.dense_jacobian(0.0, y0)
    M = jnp.eye(model.n_state) - gamma * J
    res = M @ x - b
    # V + gates + synapse rows are all exact for the non-plasticity model
    assert float(jnp.abs(res).max()) < 1e-8
    # (b) same physics, no extra Newton work, across both modes
    T, iinj = 40.0, 0.15
    outs = {}
    for mode in ("neuron", "schur"):
        opts = bdf.BDFOptions(atol=1e-3, precond=mode)
        st = bdf.reinit(model, 0.0, y0, iinj, opts)
        st = jax.jit(lambda s, o=opts: bdf.advance_to(model, s, T, iinj, o))(st)
        assert not bool(st.failed)
        outs[mode] = st
    dv = abs(float(outs["schur"].zn[0][0]) - float(outs["neuron"].zn[0][0]))
    assert dv < 0.5                        # same trajectory endpoint (mV)
    assert int(outs["schur"].nni) <= int(outs["neuron"].nni)
    assert int(outs["schur"].nncf) <= int(outs["neuron"].nncf)


def test_error_fail_q_force_rebuilds_zn1(soma_model):
    """Regression (ISSUE 4 satellite): when MAX_NEF error-test failures
    force q -> 1, ``on_err_fail`` must rebuild zn[1] = h * f(t, zn[0]) as
    CVODE does — before the fix the retry kept solving a corrupted BDF1
    history and gave up (``failed=True``) on exactly this scenario.

    Pinned to the legacy ``jac_policy="iteration"`` path: under the reuse
    policy the stale-factor Newton refuses to converge on the garbage
    prediction, so recovery runs through h-shrinking convergence retries
    and the netf force may never fire (see
    ``test_reuse_policy_recovers_from_corrupted_history``)."""
    model = soma_model
    opts = bdf.BDFOptions(jac_policy="iteration")
    st = bdf.reinit(model, 0.0, model.init_state(-65.0), 0.1, opts)
    for _ in range(8):
        st = bdf.step(model, st, 2.0, 0.1, opts)
    assert int(st.q) > 1 and not bool(st.failed)

    # corrupt the Nordsieck history rows: every prediction is garbage, so
    # the error test fails repeatedly until the q->1 force fires
    st_bad = st._replace(zn=st.zn.at[1:].multiply(1e9))
    st2 = bdf.step(model, st_bad, float(st.t) + 0.5, 0.1, opts)
    assert int(st2.netf) >= bdf.MAX_NEF          # the force path ran
    assert int(st2.q) == 1
    assert not bool(st2.failed)

    # ... and the recovered step is *accurate*: compare against the clean
    # state advanced to the same time
    ref = bdf.advance_to(model, st, float(st2.t) + 1e-12, 0.1, opts)
    ref_v = float(bdf.interpolate(ref, st2.t)[model.idx_vsoma])
    assert abs(float(st2.zn[0][model.idx_vsoma]) - ref_v) < 1e-6

    # a subsequent normal advance from the recovered state stays healthy
    st3 = bdf.advance_to(model, st2, float(st2.t) + 1.0, 0.1, opts)
    assert not bool(st3.failed)
    assert np.all(np.isfinite(np.asarray(st3.zn[0])))


def test_reuse_policy_recovers_from_corrupted_history(soma_model):
    """The freshness policy's counterpart of the force-path regression:
    from the same corrupted Nordsieck history, the stale-factor retry /
    convergence-failure ladder must still recover an accurate accepted
    step (possibly without any error-test failure at all: the stale
    Newton simply refuses to converge on garbage until h collapses)."""
    model = soma_model
    opts = bdf.BDFOptions()
    st = bdf.reinit(model, 0.0, model.init_state(-65.0), 0.1, opts)
    for _ in range(8):
        st = bdf.step(model, st, 2.0, 0.1, opts)
    st_bad = st._replace(zn=st.zn.at[1:].multiply(1e9))
    st2 = bdf.step(model, st_bad, float(st.t) + 0.5, 0.1, opts)
    assert not bool(st2.failed)
    ref = bdf.advance_to(model, st, float(st2.t) + 1e-12, 0.1, opts)
    ref_v = float(bdf.interpolate(ref, st2.t)[model.idx_vsoma])
    assert abs(float(st2.zn[0][model.idx_vsoma]) - ref_v) < 1e-6
    st3 = bdf.advance_to(model, st2, float(st2.t) + 1.0, 0.1, opts)
    assert not bool(st3.failed)
    assert np.all(np.isfinite(np.asarray(st3.zn[0])))


def test_step_or_deliver_matches_unfused_branches(soma_model):
    """The fused deliver/step entry point must reproduce both unfused
    branches: ``deliver_event`` on deliver lanes, ``step`` on step lanes
    (to fp-fusion noise — the shared rhs stream reassociates rounding)."""
    model = soma_model
    opts = bdf.BDFOptions()
    st = bdf.reinit(model, 0.0, model.init_state(-65.0), 0.15, opts)
    for _ in range(5):
        st = bdf.step(model, st, 5.0, 0.15, opts)

    st_del = bdf.deliver_event(model, st, 1e-4, 2e-5, 0.15, opts)
    st_fus = bdf.step_or_deliver(model, st, float(st.t), 1e-4, 2e-5,
                                 jnp.asarray(True), 0.15, opts)
    for f in ("t", "h", "q", "nst", "nfe", "nni", "nreset"):
        assert np.allclose(np.asarray(getattr(st_del, f)),
                           np.asarray(getattr(st_fus, f)), rtol=0, atol=0), f
    np.testing.assert_allclose(np.asarray(st_del.zn), np.asarray(st_fus.zn),
                               rtol=1e-12, atol=1e-15)

    st_stp = bdf.step(model, st, 5.0, 0.15, opts)
    st_fus2 = bdf.step_or_deliver(model, st, 5.0, 0.0, 0.0,
                                  jnp.asarray(False), 0.15, opts)
    for f in st._fields:
        np.testing.assert_allclose(np.asarray(getattr(st_stp, f)),
                                   np.asarray(getattr(st_fus2, f)),
                                   rtol=1e-12, atol=1e-18, err_msg=f)

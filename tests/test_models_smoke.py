"""Per-architecture smoke tests: reduced same-family configs, one forward /
train step / decode step on CPU, asserting shapes and finiteness (assignment
requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import lm

B, S = 2, 32


def _batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_matches_assignment(name):
    cfg = ARCHS[name]
    # every assignment hyper-parameter is an exact literal in the config
    table = {
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }
    L, d, H, Hkv, ff, V = table[name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (L, d, H, Hkv, ff, V)
    if name == "qwen2-72b":
        assert cfg.qkv_bias
    if name == "arctic-480b":
        assert cfg.n_experts == 128 and cfg.top_k == 2 and cfg.dense_residual
    if name == "qwen3-moe-30b-a3b":
        assert cfg.n_experts == 128 and cfg.top_k == 8
    if name == "zamba2-7b":
        assert cfg.ssm_state == 64


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_forward_and_shapes(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key)
    x, aux = lm.forward_hidden(cfg, params, batch)
    assert x.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(x)).all()
    loss, _ = lm.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    # loss near ln(vocab) at init
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.5


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_train_step_improves(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(1)
    params, opt = lm.init_train_state(cfg, key)
    batch = _batch(cfg, key)
    step = jax.jit(lambda p, o, b: lm.train_step(cfg, p, o, b, 1e-3))
    l0 = None
    for _ in range(5):
        params, opt, m = step(params, opt, batch)
        if l0 is None:
            l0 = float(m["loss"])
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) < l0                 # memorises the fixed batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_decode_step(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(2)
    params = lm.init_params(cfg, key)
    state = lm.make_decode_state(cfg, B, S)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    logits, state = lm.decode_step(cfg, params, tok, state, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    logits2, _ = lm.decode_step(cfg, params, tok, state, jnp.int32(1))
    assert np.isfinite(np.asarray(logits2)).all()


def test_remat_matches_no_remat():
    cfg = ARCHS["smollm-135m"].reduced()
    key = jax.random.PRNGKey(3)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key)
    l1, _ = lm.loss_fn(cfg, params, batch, remat=False)
    l2, _ = lm.loss_fn(cfg, params, batch, remat=True)
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)

"""Carefully-speculative FAP (the paper's §Discussion future-work proposal):
physics must match the non-speculative method; quiet networks must validate
nearly all speculation; active networks must pay only local discarded work
(no event is ever retracted — the cascade cannot start, by construction)."""
import numpy as np
import pytest

from repro.core import exec_fap, exec_speculative, morphology, network
from repro.core.cell import CellModel

T = 40.0


@pytest.fixture(scope="module")
def setup():
    model = CellModel(morphology.soma_only())
    net = network.make_network(16, k_in=4, seed=5)
    return model, net


def _trains(res):
    ts = np.asarray(res.rec.times)
    c = np.asarray(res.rec.count)
    return [np.sort(ts[i][: c[i]]) for i in range(len(c))]


def test_quiet_speculation_validates_and_extends_steps(setup):
    model, net = setup
    iinj = np.zeros(net.n)
    run = exec_speculative.make_spec_runner(model, net, iinj, T,
                                            spec_window=4.0)
    res, stats, rounds = run()
    assert not bool(res.failed)
    assert int(stats.backsteps) == 0              # nothing to invalidate
    assert int(stats.hits) > 0                    # speculation engaged
    # fewer validated steps than the non-speculative run (longer spans)
    r_ns = exec_fap.run_fap_vardt(model, net, iinj, T)
    assert int(res.n_steps) < int(r_ns.n_steps)


def test_active_network_matches_nonspeculative_physics(setup):
    model, net = setup
    rng = np.random.default_rng(2)
    iinj = 0.16 + 0.004 * rng.standard_normal(net.n)
    run = exec_speculative.make_spec_runner(model, net, iinj, T)
    res, stats, rounds = run()
    assert not bool(res.failed)
    assert int(res.dropped) == 0
    r_ns = exec_fap.run_fap_vardt(model, net, iinj, T)
    ta, tb = _trains(res), _trains(r_ns)
    mismatched = sum(len(a) != len(b) for a, b in zip(ta, tb))
    assert mismatched <= 2                        # near-threshold flips only
    for a, b in zip(ta, tb):
        if len(a) == len(b) and len(a):
            assert np.abs(a - b).max() < 0.25
    tot_a, tot_b = sum(map(len, ta)), sum(map(len, tb))
    assert abs(tot_a - tot_b) <= max(2, 0.1 * tot_b)


def test_backsteps_are_local_and_counted(setup):
    """With events flying, some speculation must be discarded — and the
    counter proves the mechanism exercised; no queue overflow / retraction
    pathway exists by construction."""
    model, net = setup
    rng = np.random.default_rng(3)
    iinj = 0.17 + 0.004 * rng.standard_normal(net.n)
    run = exec_speculative.make_spec_runner(model, net, iinj, T,
                                            spec_window=4.0)
    res, stats, rounds = run()
    assert not bool(res.failed)
    assert int(stats.backsteps) + int(stats.hits) > 0
    assert int(stats.wasted_steps) >= int(stats.backsteps)

"""Hypothesis property sweeps of the event queues (dense AND wheel).

Skipped cleanly when the optional ``hypothesis`` dev dependency (see
requirements-dev.txt) is not installed; the deterministic queue tests in
test_events.py / test_event_wheel.py still run.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro import sched  # noqa: E402
from repro.core import events as ev  # noqa: E402

# bucket_slots >= max events per example, so no (neuron, bucket) collision
# pattern can overflow the wheel — the properties assume capacity suffices
WHEEL = sched.WheelSpec(n_buckets=16, bucket_slots=32, bucket_width=0.5)


def _queues(n: int):
    return (("dense", ev.make_queue(n, WHEEL.capacity), ev.insert,
             ev.deliver_until),
            ("wheel", sched.make_wheel(n, WHEEL),
             lambda eq, *a: sched.insert(WHEEL, eq, *a),
             sched.deliver_until))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7),
                          st.floats(0.01, 100.0, allow_nan=False)),
                min_size=1, max_size=32))
def test_no_event_lost_property(evs):
    """Every valid inserted event is delivered exactly once, with its exact
    weight, provided capacity suffices — for both queue implementations."""
    n = 8
    tgt = jnp.array([e[0] for e in evs], jnp.int32)
    t = jnp.array([e[1] for e in evs])
    wa = jnp.ones(len(evs))
    per_target = np.zeros(n)
    for tg, _ in evs:
        per_target[tg] += 1.0
    for name, eq, insert, deliver in _queues(n):
        eq = insert(eq, tgt, t, wa, jnp.zeros(len(evs)),
                    jnp.ones(len(evs), bool))
        assert int(eq.dropped) == 0, name
        eq, da, _, cnt = deliver(eq, jnp.full((n,), 1e9))
        np.testing.assert_allclose(np.asarray(da), per_target,
                                   err_msg=name)
        assert int(cnt.sum()) == len(evs), name
        assert np.isinf(np.asarray(eq.t)).all(), name   # fully drained


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_partial_delivery_order_property(seed):
    """Delivering up to t only pops events <= t; later events remain —
    identically for the dense queue and the event wheel."""
    rng = np.random.default_rng(seed)
    n, E = 4, 20
    tgt = jnp.asarray(rng.integers(0, n, E), jnp.int32)
    t = jnp.asarray(rng.uniform(0, 10, E))
    cut = float(rng.uniform(0, 10))
    expect = np.zeros(n)
    for tg, tt in zip(np.asarray(tgt), np.asarray(t)):
        if tt <= cut:
            expect[tg] += 1
    for name, eq, insert, deliver in _queues(n):
        eq = insert(eq, tgt, t, jnp.ones(E), jnp.zeros(E), jnp.ones(E, bool))
        eq2, da, _, cnt = deliver(eq, jnp.full((n,), cut))
        np.testing.assert_allclose(np.asarray(da), expect, err_msg=name)
        remaining = np.asarray(eq2.t)
        assert (remaining[np.isfinite(remaining)] > cut).all(), name

"""Tenant-isolated simulation serving (``repro.serve``).

The golden isolation contract, extending ``test_robustness.py``'s
identity pattern to the tenant axis: because lanes are independent under
``jax.vmap`` and an inactive lane's round is a semantic no-op, a
tenant's final spike train is BITWISE identical whether it ran solo, in
a full batch, next to a poisoned neighbour, or through its own
quarantine/retry cycle.  Every service here shares one runner — and via
the runner-cached ``serve_vround`` literally one compiled round — so the
comparisons are jaxpr-for-jaxpr, not merely value-close.

Accounting contract (detected, never silent): every submitted request
terminates in exactly one of {completed, evicted, rejected}, retries are
bounded by the backoff budget, and shedding is always an explicit
rejection.  ``ServeResult.assert_accounting`` runs after every service
``run()`` by construction; the Hypothesis property fuzzes the mix.
"""
import numpy as np
import pytest

from repro.checkpoint import (ExponentialBackoff, FaultPlan,
                              latest_tenant_step, list_tenants,
                              restore_tenant_checkpoint)
from repro.checkpoint.fault_tolerance import StragglerMonitor
from repro.core import exec_fap, morphology, network
from repro.core.cell import CellModel
from repro.serve import SimService, TenantRequest

N = 10
T_END = 6.0
LANES = 3          # fixed so every service reuses the same compiled shapes


@pytest.fixture(scope="module")
def runner():
    model = CellModel(morphology.soma_only())
    net = network.make_network(N, k_in=4, seed=3)
    return exec_fap.make_fap_vardt_runner(model, net, 0.0, T_END)


def _reqs(n=3, **kw):
    return [TenantRequest(rid=r, iinj=0.14 + 0.012 * r, **kw)
            for r in range(n)]


def _svc(runner, **kw):
    kw.setdefault("lanes", LANES)
    return SimService(runner=runner, t_end=T_END, **kw)


def _run(runner, reqs, **kw):
    svc = _svc(runner, **kw)
    for r in reqs:
        svc.submit(r)
    return svc.run()


def _spikes(res, rid):
    r = res.results[rid]
    assert r.status == "completed", r
    return r.times, r.count


@pytest.fixture(scope="module")
def batch_baseline(runner):
    """Fault-free 3-tenant batch — the identity target."""
    res = _run(runner, _reqs())
    assert res.completed == 3 and res.evicted == res.rejected == 0
    return res


@pytest.fixture(scope="module")
def solo_baselines(runner):
    """Each tenant alone in an identical service (same lane count, same
    compiled round) — the solo side of the identity."""
    out = {}
    for r in _reqs():
        res = _run(runner, [r])
        assert res.completed == 1
        out[r.rid] = _spikes(res, r.rid)
    return out


def test_solo_vs_batch_identity(batch_baseline, solo_baselines):
    """A tenant's spike train is independent of who shares the batch."""
    for rid in range(3):
        tb, cb = _spikes(batch_baseline, rid)
        ts, cs = solo_baselines[rid]
        assert np.array_equal(tb, ts)
        assert np.array_equal(cb, cs)


def test_poison_quarantine_retry_identity(runner, batch_baseline,
                                          solo_baselines):
    """FaultPlan poisons tenant 1 mid-run: it is quarantined, rolled back
    to its own snapshot, retried, and completes bit-identically to its
    solo run; every OTHER tenant is bit-identical to the fault-free
    batch — the end-to-end isolation acceptance criterion."""
    fault = FaultPlan(poison_at_round=8, poison_tenant=1, poison_lane=2)
    res = _run(runner, _reqs(), fault=fault)
    assert res.completed == 3
    assert res.quarantines >= 1 and res.retried >= 1
    assert res.results[1].retries >= 1
    assert res.results[1].health["nonfinite_rounds"] >= 1
    for rid in range(3):
        tp, cp = _spikes(res, rid)
        tb, cb = _spikes(batch_baseline, rid)
        assert np.array_equal(tp, tb), f"rid {rid} perturbed by the fault"
        assert np.array_equal(cp, cb)
        assert np.array_equal(tp, solo_baselines[rid][0])


def test_retry_exhaustion_evicts(runner, batch_baseline):
    """A zero-retry-budget tenant is evicted on its first quarantine —
    explicitly, with reason — and its neighbours still complete
    bit-identically to the fault-free batch."""
    reqs = [TenantRequest(rid=0, iinj=0.14),
            TenantRequest(rid=1, iinj=0.152, max_retries=0),
            TenantRequest(rid=2, iinj=0.164)]
    fault = FaultPlan(poison_at_round=5, poison_tenant=1, poison_lane=0)
    res = _run(runner, reqs, fault=fault)
    assert res.results[1].status == "evicted"
    assert res.results[1].reason == "retries_exhausted"
    assert res.evicted == 1 and res.completed == 2
    for rid in (0, 2):
        tp, cp = _spikes(res, rid)
        tb, cb = _spikes(batch_baseline, rid)
        assert np.array_equal(tp, tb) and np.array_equal(cp, cb)


def test_deadline_eviction(runner):
    """A round-deadline tenant is evicted at the bound, never silently
    kept running; the unconstrained tenant completes."""
    reqs = [TenantRequest(rid=0, iinj=0.15, deadline_rounds=3),
            TenantRequest(rid=1, iinj=0.15)]
    res = _run(runner, reqs)
    assert res.results[0].status == "evicted"
    assert res.results[0].reason == "deadline_rounds"
    assert res.results[0].rounds == 3
    assert res.results[1].status == "completed"


def test_queue_full_sheds_lowest_qos(runner):
    """An overloaded queue sheds ONLY the lowest-QoS requests, each with
    an explicit rejection; every high-QoS request completes."""
    svc = _svc(runner, queue_cap=3)
    hi = [TenantRequest(rid=r, iinj=0.15, qos=2) for r in range(3)]
    lo = [TenantRequest(rid=10 + r, iinj=0.15, qos=0) for r in range(3)]
    # fill the queue with low-QoS, then submit high-QoS into the overflow:
    # each high submit must displace a queued low request explicitly
    for r in lo:
        svc.submit(r)
    for r in hi:
        svc.submit(r)
    res = svc.run()
    assert res.shed == 3 and res.rejected == 3
    for r in lo:
        assert res.results[r.rid].status == "rejected"
        assert res.results[r.rid].reason == "shed:queue_full"
    for r in hi:
        assert res.results[r.rid].status == "completed"


def test_overload_shed_on_sustained_regression(runner):
    """Sustained straggler regression sheds a queued low-QoS request with
    an explicit "shed:overload" rejection (deterministic: the monitor is
    pre-loaded with a regressed window)."""
    mon = StragglerMonitor(window=32, threshold=2.0)
    for _ in range(10):
        mon.record(0.01)
    for _ in range(8):
        mon.record(1.0)          # flagged vs the 0.01 median
    assert mon.sustained()
    svc = _svc(runner, straggler=mon, queue_cap=8)
    for r in _reqs(LANES, qos=2):
        svc.submit(r)
    svc.submit(TenantRequest(rid=99, iinj=0.15, qos=0))    # queued: no lane
    assert svc.step()
    assert svc.res.results[99].status == "rejected"
    assert svc.res.results[99].reason == "shed:overload"
    res = svc.run()
    assert res.shed == 1 and res.completed == LANES
    assert res.health["straggler"]["threshold"] == 2.0


def test_qos_frontier_cap_slows_not_starves(runner):
    """A QoS frontier cap throttles a tenant (more service rounds to the
    same sim-time target) but never starves it — progress is guaranteed
    by the conservative-lookahead argument under any cap."""
    fast = _run(runner, [TenantRequest(rid=0, iinj=0.15, qos=1)])
    slow = _run(runner, [TenantRequest(rid=0, iinj=0.15, qos=0)],
                qos_caps={0: 2})
    assert fast.results[0].status == "completed"
    assert slow.results[0].status == "completed"
    assert slow.results[0].rounds > fast.results[0].rounds


def test_per_tenant_checkpoints(runner, tmp_path):
    """Durable per-tenant snapshots: each tenant commits to its own
    atomic checkpoint dir and restores leaf-for-leaf."""
    res = _run(runner, _reqs(2), ckpt_dir=str(tmp_path), checkpoint_every=5)
    assert res.completed == 2
    tenants = list_tenants(str(tmp_path))
    assert tenants == [0, 1]
    for rid in tenants:
        step = latest_tenant_step(str(tmp_path), rid)
        assert step is not None and step % 5 == 0
        like = runner.pack(runner.init_carry(0.0))
        carry, extras = restore_tenant_checkpoint(str(tmp_path), rid, step,
                                                  like)
        assert extras["tenant"] == rid and extras["rid"] == rid
        assert np.isfinite(np.asarray(carry.sts.t)).all()


def test_exponential_backoff_policy():
    bo = ExponentialBackoff(base=2, factor=2.0, cap=16, max_retries=4)
    assert [bo.delay(a) for a in range(1, 5)] == [2, 4, 8, 16]
    assert bo.budget() == 30
    assert ExponentialBackoff(cap=5).delay(10) == 5
    with pytest.raises(ValueError):
        bo.delay(0)


def test_straggler_monitor_knobs():
    """Threshold/window are constructor knobs and ride stats()."""
    tight = StragglerMonitor(window=16, threshold=1.5)
    loose = StragglerMonitor(window=16, threshold=50.0)
    for m in (tight, loose):
        for _ in range(10):
            m.record(0.01)
        m.record(0.2)
    assert tight.flagged == 1 and loose.flagged == 0
    s = tight.stats()
    assert s["window"] == 16 and s["threshold"] == 1.5
    assert not loose.sustained()


def test_merge_slot_state_isolates_prefill():
    """The LM serving prefill fix: committing a decode-state update to
    one slot leaves every other slot's cache bitwise untouched (batch
    axis 1 on every leaf family)."""
    from repro.launch.serve import merge_slot_state
    rng = np.random.default_rng(0)
    B = 4
    old = {"kv": rng.standard_normal((2, B, 8, 3)),
           "ssm": (rng.standard_normal((5, B, 7)),
                   rng.standard_normal((1, B, 2, 2, 2)))}
    new = {"kv": rng.standard_normal((2, B, 8, 3)),
           "ssm": (rng.standard_normal((5, B, 7)),
                   rng.standard_normal((1, B, 2, 2, 2)))}
    got = merge_slot_state(new, old, 2, B)
    for kn, ko, kg in ((new["kv"], old["kv"], got["kv"]),
                       (new["ssm"][0], old["ssm"][0], got["ssm"][0]),
                       (new["ssm"][1], old["ssm"][1], got["ssm"][1])):
        kg = np.asarray(kg)
        assert np.array_equal(kg[:, 2], kn[:, 2])
        for b in (0, 1, 3):
            assert np.array_equal(kg[:, b], ko[:, b])


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_admission_partition_seeded(runner, seed):
    """Deterministic fallback of the Hypothesis property (the container
    may lack hypothesis): random tenant mixes still partition into
    exactly one terminal state each, with retries inside the budget."""
    rng = np.random.default_rng(seed)
    bo = ExponentialBackoff(max_retries=2)
    fault = FaultPlan(poison_at_round=3,
                      poison_tenant=int(rng.integers(0, 6)),
                      poison_lane=int(rng.integers(0, N)))
    svc = _svc(runner, queue_cap=int(rng.integers(1, 5)), backoff=bo,
               fault=fault, qos_caps={0: 3})
    n_req = int(rng.integers(1, 8))
    for rid in range(n_req):
        svc.submit(TenantRequest(
            rid=rid, iinj=float(0.13 + 0.03 * rng.random()),
            qos=int(rng.integers(0, 3)),
            deadline_rounds=int(rng.integers(0, 2) * rng.integers(2, 30))))
    res = svc.run(max_rounds=2000)
    res.assert_accounting()
    assert res.submitted == n_req
    for r in res.results.values():
        assert r.retries <= bo.max_retries
        assert (r.status == "completed") == (r.times is not None)


def test_admission_property(runner):
    """Hypothesis: under a random tenant mix (QoS classes, deadlines, a
    random poison target, a small queue) every submitted request lands in
    exactly one terminal state, retries stay within the backoff budget,
    and nothing is silently dropped."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=6, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(n_req=st.integers(1, 7), queue_cap=st.integers(1, 4),
               poison=st.integers(0, 7), seed=st.integers(0, 99),
               deadline=st.integers(0, 2))
    def prop(n_req, queue_cap, poison, seed, deadline):
        rng = np.random.default_rng(seed)
        bo = ExponentialBackoff(max_retries=2)
        fault = FaultPlan(poison_at_round=3, poison_tenant=poison,
                          poison_lane=int(rng.integers(0, N)))
        svc = _svc(runner, queue_cap=queue_cap, backoff=bo, fault=fault,
                   qos_caps={0: 3})
        for rid in range(n_req):
            svc.submit(TenantRequest(
                rid=rid, iinj=float(0.13 + 0.03 * rng.random()),
                qos=int(rng.integers(0, 3)),
                deadline_rounds=int(deadline * rng.integers(2, 30))))
        res = svc.run(max_rounds=2000)
        res.assert_accounting()          # the exactly-one-terminal check
        assert res.submitted == n_req
        for r in res.results.values():
            assert r.retries <= bo.max_retries
            assert (r.status == "completed") == (r.times is not None)

    prop()

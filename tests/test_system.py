"""End-to-end behaviour tests for the paper's system: full launcher runs,
serving loop, and the paper's headline qualitative claims at test scale."""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(args, timeout=560):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run([sys.executable, "-m"] + args, env=env, cwd=ROOT,
                          capture_output=True, text=True, timeout=timeout)


def test_train_launcher_end_to_end(tmp_path):
    res = _run(["repro.launch.train", "--arch", "smollm-135m", "--reduced",
                "--steps", "25", "--batch", "4", "--seq", "64",
                "--ckpt-dir", str(tmp_path), "--save-every", "10"])
    assert res.returncode == 0, res.stderr[-2000:]
    assert "done: steps=25" in res.stdout
    first = float(res.stdout.split("loss ")[-1].split(" ->")[0])
    last = float(res.stdout.strip().split("-> ")[-1])
    assert last < first
    # checkpoints exist and resume works
    res2 = _run(["repro.launch.train", "--arch", "smollm-135m", "--reduced",
                 "--steps", "30", "--batch", "4", "--seq", "64",
                 "--ckpt-dir", str(tmp_path), "--save-every", "10"])
    assert res2.returncode == 0, res2.stderr[-2000:]
    assert "start_step=25" in res2.stdout


def test_train_launcher_failure_injection_and_resume(tmp_path):
    res = _run(["repro.launch.train", "--arch", "smollm-135m", "--reduced",
                "--steps", "20", "--batch", "2", "--seq", "32",
                "--ckpt-dir", str(tmp_path), "--save-every", "5",
                "--inject-failure-at", "12"])
    assert res.returncode == 75                     # preempted
    res2 = _run(["repro.launch.train", "--arch", "smollm-135m", "--reduced",
                 "--steps", "20", "--batch", "2", "--seq", "32",
                 "--ckpt-dir", str(tmp_path), "--save-every", "5"])
    assert res2.returncode == 0, res2.stderr[-2000:]
    assert "start_step=10" in res2.stdout           # resumed from last save


def test_serve_launcher(tmp_path):
    res = _run(["repro.launch.serve", "--arch", "smollm-135m", "--reduced",
                "--requests", "4", "--slots", "2", "--max-new", "4",
                "--cache-len", "64"])
    assert res.returncode == 0, res.stderr[-2000:]
    assert "served 4 requests" in res.stdout


def test_paper_headline_claim_quiet_speedup():
    """The paper's central result at test scale: for quiet dynamics the FAP
    variable-step method takes far fewer interpolation steps than the
    reference fixed-step method (544-65x in the paper at scale; we assert
    the step-count mechanism, which wall-clock follows on real hardware)."""
    from repro.core import exec_bsp, exec_fap, morphology, network
    from repro.core.cell import CellModel
    model = CellModel(morphology.soma_only())
    net = network.make_network(24, k_in=4, seed=0)
    iinj = np.zeros(24)                             # quiet regime
    T = 50.0
    r_fixed = exec_bsp.run_bsp_fixed(model, net, iinj, T,
                                     method="derivimplicit")
    r_fap = exec_fap.run_fap_vardt(model, net, iinj, T)
    assert not bool(r_fap.failed)
    ratio = int(r_fixed.n_steps) / max(int(r_fap.n_steps), 1)
    assert ratio > 10, f"step reduction only {ratio:.1f}x"

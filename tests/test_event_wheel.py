"""Event-wheel scheduler subsystem: insert/deliver equivalence against the
dense argsort queue, overflow accounting, sort-free jaxpr certification,
the fused horizon/selection kernel, and FAP end-to-end equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched
from repro.core import events as ev
from repro.core import exec_common as xc
from repro.core import exec_fap, morphology, network
from repro.core.cell import CellModel
from repro.kernels.event_wheel import ops as ew_ops
from repro.kernels.event_wheel import ref as ew_ref
from repro.kernels.event_wheel.event_wheel import horizon_score_pallas

SPEC = sched.WheelSpec(n_buckets=8, bucket_slots=4, bucket_width=0.5)


def _drain_equal(deq, weq, n):
    """Both queues must deliver identical per-neuron sums/counts and agree
    on next_time at every point."""
    np.testing.assert_allclose(np.asarray(ev.next_time(deq)),
                               np.asarray(sched.next_time(weq)))
    d2, da, dg, dc = ev.deliver_until(deq, jnp.full((n,), 1e9))
    w2, wa, wg, wc = sched.deliver_until(weq, jnp.full((n,), 1e9))
    np.testing.assert_allclose(np.asarray(da), np.asarray(wa))
    np.testing.assert_allclose(np.asarray(dg), np.asarray(wg))
    assert (np.asarray(dc) == np.asarray(wc)).all()
    assert np.isinf(np.asarray(w2.t)).all()


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_wheel_matches_dense_random_traffic(seed):
    """Randomized traffic with interleaved partial deliveries: the wheel is
    event-for-event equivalent to the dense queue (dropped == 0 both)."""
    rng = np.random.default_rng(seed)
    n = 8
    deq = ev.make_queue(n, SPEC.capacity)
    weq = sched.make_wheel(n, SPEC)
    for _ in range(4):
        E = int(rng.integers(5, 25))
        tgt = jnp.asarray(rng.integers(0, n, E), jnp.int32)
        t = jnp.asarray(rng.uniform(0, 10, E))
        wa = jnp.asarray(rng.exponential(1.0, E))
        wg = jnp.asarray(rng.exponential(1.0, E))
        valid = jnp.asarray(rng.random(E) < 0.8)
        deq = ev.insert(deq, tgt, t, wa, wg, valid)
        weq = sched.insert(SPEC, weq, tgt, t, wa, wg, valid)
        assert int(deq.dropped) == int(weq.dropped) == 0
        cut = jnp.asarray(rng.uniform(0, 10, n))
        deq, da, dg, dc = ev.deliver_until(deq, cut)
        weq, wa_, wg_, wc = sched.deliver_until(weq, cut)
        np.testing.assert_allclose(np.asarray(da), np.asarray(wa_))
        np.testing.assert_allclose(np.asarray(dg), np.asarray(wg_))
        assert (np.asarray(dc) == np.asarray(wc)).all()
        np.testing.assert_allclose(np.asarray(ev.next_time(deq)),
                                   np.asarray(sched.next_time(weq)))
    _drain_equal(deq, weq, n)


def test_wheel_grouped_matches_generic():
    """The grouped (static fan-out layout) fast path places the same event
    set as the generic scatter-min path."""
    rng = np.random.default_rng(5)
    n, k = 12, 6
    t = jnp.asarray(rng.uniform(0, 10, (n, k)))
    wa = jnp.asarray(rng.exponential(1.0, (n, k)))
    wg = jnp.asarray(rng.exponential(1.0, (n, k)))
    valid = jnp.asarray(rng.random((n, k)) < 0.6)
    wq_g = sched.insert_grouped(SPEC, sched.make_wheel(n, SPEC),
                                t, wa, wg, valid)
    tgt = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    wq = sched.insert(SPEC, sched.make_wheel(n, SPEC), tgt, t.reshape(-1),
                      wa.reshape(-1), wg.reshape(-1), valid.reshape(-1))
    assert int(wq_g.dropped) == int(wq.dropped) == 0
    np.testing.assert_allclose(np.asarray(sched.next_time(wq_g)),
                               np.asarray(sched.next_time(wq)))
    _, a1, g1, c1 = sched.deliver_until(wq_g, jnp.full((n,), 1e9))
    _, a2, g2, c2 = sched.deliver_until(wq, jnp.full((n,), 1e9))
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2))
    assert (np.asarray(c1) == np.asarray(c2)).all()


def test_overflow_accounting_matches_dense():
    """Overflow is detected, never silent, and matches the dense queue when
    the capacity topologies coincide: E same-time events into one neuron hit
    one bucket of S slots; a dense queue with capacity S drops the same
    events (the later-index ones)."""
    spec = sched.WheelSpec(n_buckets=4, bucket_slots=3, bucket_width=0.5)
    n, E = 2, 7
    tgt = jnp.zeros(E, jnp.int32)
    t = jnp.full((E,), 1.2)                     # all in one bucket
    w = jnp.arange(1.0, E + 1.0)                # distinguishable weights
    valid = jnp.ones(E, bool)
    deq = ev.insert(ev.make_queue(n, spec.bucket_slots), tgt, t, w,
                    jnp.zeros(E), valid)
    weq = sched.insert(spec, sched.make_wheel(n, spec), tgt, t, w,
                       jnp.zeros(E), valid)
    assert int(deq.dropped) == int(weq.dropped) == E - spec.bucket_slots
    # the SAME events survive (stable index order within the group)
    _, da, _, _ = ev.deliver_until(deq, jnp.full((n,), 10.0))
    _, wa, _, _ = sched.deliver_until(weq, jnp.full((n,), 10.0))
    np.testing.assert_allclose(np.asarray(da), np.asarray(wa))


def test_wheel_insert_jaxpr_sort_free():
    """Acceptance: the wheel insert path lowers with NO sort primitive —
    the dense path's global argsort (a distributed sort under GSPMD) is
    gone.  Sanity: the dense insert does contain one."""
    rng = np.random.default_rng(0)
    n, E = 16, 64
    tgt = jnp.asarray(rng.integers(0, n, E), jnp.int32)
    t = jnp.asarray(rng.uniform(0, 10, E))
    w = jnp.asarray(rng.exponential(1.0, E))
    valid = jnp.ones(E, bool)
    weq = sched.make_wheel(n, SPEC)
    prims = sched.jaxpr_primitives(
        lambda q: sched.insert(SPEC, q, tgt, t, w, w, valid), weq)
    assert "sort" not in prims, prims
    k = E // n
    prims_g = sched.jaxpr_primitives(
        lambda q: sched.insert_grouped(SPEC, q, t.reshape(n, k),
                                       w.reshape(n, k), w.reshape(n, k),
                                       valid.reshape(n, k)), weq)
    assert "sort" not in prims_g, prims_g
    prims_d = sched.jaxpr_primitives(
        lambda q: ev.insert(q, tgt, t, w, w, valid), ev.make_queue(n, 32))
    assert "sort" in prims_d


def test_threshold_select_jaxpr_sort_free():
    score = jnp.asarray(np.random.default_rng(0).uniform(0, 5, 64))
    prims = sched.jaxpr_primitives(
        lambda s: ew_ops.select_threshold(s, 8), score)
    assert "sort" not in prims, prims


def test_segment_rank_matches_stable_order():
    """Ranks follow event-index order within each key group; events beyond
    max_rank keep the max_rank sentinel (they could never fit anyway)."""
    max_rank = 8
    rng = np.random.default_rng(2)
    key = jnp.asarray(rng.integers(0, 5, 40), jnp.int32)
    rank = np.asarray(sched.segment_rank(key, 5, max_rank))
    seen = {}
    for i, k in enumerate(np.asarray(key)):
        expect = seen.get(int(k), 0)
        assert rank[i] == min(expect, max_rank), (i, k, rank[i], expect)
        seen[int(k)] = expect + 1


def test_fused_horizon_matches_scatter_min():
    """The Pallas kernel (interpret off-TPU) reproduces the jnp scatter-min
    horizon and runnable mask exactly, including padding tails."""
    rng = np.random.default_rng(4)
    for n in (13, 256):
        net = network.make_network(n, k_in=4, seed=int(n))
        t_clock = jnp.asarray(rng.uniform(0.0, 3.0, n))
        pre_byk, delay_byk = ew_ops.by_post_layout(net)
        hor, run = ew_ops.fused_horizon_select(
            t_clock, pre_byk, delay_byk, t_end=50.0, horizon_cap=2.0)
        dnet = xc.to_device(net)
        hor_ref = jnp.minimum(xc.horizon_times(dnet, n, t_clock, 50.0),
                              t_clock + 2.0)
        np.testing.assert_allclose(np.asarray(hor), np.asarray(hor_ref))
        run_ref = np.asarray(t_clock) < np.asarray(hor_ref) - 1e-12
        assert (np.asarray(run) == run_ref).all()


def test_horizon_kernel_matches_ref_oracle():
    rng = np.random.default_rng(6)
    K, N = 5, 256
    cand = jnp.asarray(rng.uniform(0.0, 10.0, (K, N)))
    t_clock = jnp.asarray(rng.uniform(0.0, 8.0, N))
    hor, score = horizon_score_pallas(cand, t_clock, t_end=6.0,
                                      horizon_cap=2.0, interpret=True)
    hor_r, score_r = ew_ref.horizon_score_ref(cand, t_clock, t_end=6.0,
                                              horizon_cap=2.0)
    np.testing.assert_allclose(np.asarray(hor), np.asarray(hor_r))
    np.testing.assert_allclose(np.asarray(score), np.asarray(score_r))


def test_threshold_select_matches_sort_oracle():
    """Bisection threshold-count selection == the sort-based kth threshold
    for well-separated scores, and >= min(k, runnable) always."""
    rng = np.random.default_rng(7)
    for trial in range(5):
        score = np.where(rng.random(100) < 0.7, rng.uniform(0, 5, 100),
                         np.inf)
        s = jnp.asarray(score)
        for k in (1, 5, 30, 99):
            mask_ref = np.asarray(ew_ref.select_earliest_ref(s, k))
            tau = ew_ops.select_threshold(s, k)
            mask = np.asarray(jnp.isfinite(s) & (s <= tau))
            assert (mask == mask_ref).all(), (trial, k)
            n_run = np.isfinite(score).sum()
            assert mask.sum() >= min(k, n_run)


@pytest.fixture(scope="module")
def small_run():
    model = CellModel(morphology.soma_only())
    net = network.make_network(12, k_in=4, seed=3)
    rng = np.random.default_rng(1)
    iinj = 0.16 + 0.004 * rng.standard_normal(12)
    return model, net, iinj


def _trains(res):
    ts = np.asarray(res.rec.times)
    c = np.asarray(res.rec.count)
    return [np.sort(ts[i][: c[i]]) for i in range(len(c))]


def test_fap_e2e_wheel_equals_dense(small_run):
    """Acceptance: queue="wheel" produces spike trains IDENTICAL to the
    dense queue (delivery is a set operation; only slot placement differs)."""
    model, net, iinj = small_run
    r_d = exec_fap.run_fap_vardt(model, net, iinj, 15.0)
    r_w = exec_fap.run_fap_vardt(model, net, iinj, 15.0, queue="wheel")
    assert int(r_d.dropped) == int(r_w.dropped) == 0
    assert not bool(r_w.failed)
    td, tw = _trains(r_d), _trains(r_w)
    assert int(r_d.rec.count.sum()) > 0          # network actually active
    for a, b in zip(td, tw):
        assert len(a) == len(b)
        if len(a):
            np.testing.assert_allclose(a, b, atol=1e-9)
    assert int(r_d.n_events) == int(r_w.n_events)


def test_fap_e2e_fused_threshold_scheduler(small_run):
    """Full sort-free round (wheel queue + fused Pallas horizon + threshold
    earliest-K) against the dense/sort scheduler with the same K."""
    model, net, iinj = small_run
    r_s = exec_fap.run_fap_vardt(model, net, iinj, 15.0, k_select=4)
    r_f = exec_fap.run_fap_vardt(model, net, iinj, 15.0, k_select=4,
                                 queue="wheel", horizon_impl="fused",
                                 select="threshold")
    assert int(r_f.dropped) == 0 and not bool(r_f.failed)
    ts, tf = _trains(r_s), _trains(r_f)
    mismatched = sum(len(a) != len(b) for a, b in zip(ts, tf))
    assert mismatched <= 1
    for a, b in zip(ts, tf):
        if len(a) == len(b) and len(a):
            assert np.abs(a - b).max() < 0.25


def test_wheel_auto_sizing_no_overflow(small_run):
    """WheelSpec.auto sizes width from the delay distribution and slots from
    the in-degree: a full FAP run on make_network traffic must not overflow,
    and the occupancy telemetry must stay within the auto-sized geometry."""
    model, net, iinj = small_run
    spec = sched.WheelSpec.auto(net)
    # one revolution must span the event horizon (max delay + horizon cap)
    assert spec.n_buckets * spec.bucket_width >= float(net.delay.max()) + 2.0
    assert spec.capacity >= sched.grouped_k(net)
    r = exec_fap.run_fap_vardt(model, net, iinj, 15.0, queue="wheel",
                               wheel=spec)
    assert int(r.dropped) == 0 and not bool(r.failed)
    assert int(r.rec.count.sum()) > 0


def test_wheel_auto_survives_synchronized_burst():
    """Worst-case bucket load: every in-edge fires at t=0, so each neuron
    receives all k_in events at its in-edge delays — the lognormal delay
    mode piles them into one bucket-width window.  auto sizing computes the
    exact per-neuron sliding-window burst load, so nothing may drop."""
    n, k = 64, 16
    net = network.make_network(n, k_in=k, seed=9)
    spec = sched.WheelSpec.auto(net)
    t_ev = jnp.asarray(net.delay.reshape(n, k))
    eq = sched.insert_grouped(spec, sched.make_wheel(n, spec), t_ev,
                              jnp.ones((n, k)), jnp.zeros((n, k)),
                              jnp.ones((n, k), bool))
    assert int(eq.dropped) == 0
    occ = sched.bucket_occupancy(spec, eq)
    assert int(occ["occupied"]) == n * k
    assert int(occ["max_bucket"]) <= spec.bucket_slots


def test_bucket_occupancy_telemetry():
    """Occupancy counts exactly the pending events, per bucket."""
    spec = sched.WheelSpec(n_buckets=4, bucket_slots=3, bucket_width=0.5)
    eq = sched.make_wheel(3, spec)
    occ = sched.bucket_occupancy(spec, eq)
    assert int(occ["occupied"]) == 0 and int(occ["max_bucket"]) == 0
    tgt = jnp.asarray([0, 0, 1], jnp.int32)
    t = jnp.asarray([0.2, 0.3, 1.2])        # buckets 0, 0, 2
    eq = sched.insert(spec, eq, tgt, t, jnp.ones(3), jnp.zeros(3),
                      jnp.ones(3, bool))
    occ = sched.bucket_occupancy(spec, eq)
    assert int(occ["occupied"]) == 3
    assert np.asarray(occ["per_bucket"]).tolist() == [2, 0, 1, 0]
    assert int(occ["max_bucket"]) == 2


def test_bsp_wheel_equals_dense(small_run):
    """The knob is wired through the BSP models too."""
    from repro.core import exec_bsp
    model, net, iinj = small_run
    r_d = exec_bsp.run_bsp_fixed(model, net, iinj, 10.0)
    r_w = exec_bsp.run_bsp_fixed(model, net, iinj, 10.0, queue="wheel")
    assert int(r_w.dropped) == 0
    td, tw = _trains(r_d), _trains(r_w)
    for a, b in zip(td, tw):
        np.testing.assert_allclose(a, b, atol=1e-9)


# ---------------------------------------------------------------------------
# ISSUE 5: compact fan-out building blocks — the compact-and-gather kernel,
# the pairwise segment-ranking kernel, and the dense queue's flat batch
# insert (all bit-compared against their reference twins)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,mo,cap", [(40, 3, 8), (300, 5, 16), (256, 4, 300)])
def test_compact_gather_pallas_matches_ref(n, mo, cap):
    rng = np.random.default_rng(n + mo)
    table = jnp.asarray(rng.integers(0, 999, (n, mo)).astype(np.int32))
    for frac in (0.0, 0.2, 0.9):
        mask = jnp.asarray(rng.random(n) < frac)
        ia, ra, ca = ew_ref.compact_gather_ref(mask, table, cap, fill=777)
        ib, rb, cb = ew_ops.compact_gather(mask, table, cap, fill=777,
                                           impl="pallas")
        assert int(ca) == int(cb) == int(mask.sum())
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
        np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))
        # slot r holds the table row of the r-th set lane, fill-padded
        want = np.flatnonzero(np.asarray(mask))[:cap]
        np.testing.assert_array_equal(np.asarray(ra)[: len(want)],
                                      np.asarray(table)[want])
        assert (np.asarray(ra)[len(want):] == 777).all()


@pytest.mark.parametrize("E,n_keys,max_rank", [(37, 12, 4), (600, 50, 6),
                                               (512, 7, 3)])
def test_segment_rank_pallas_matches_scatter(E, n_keys, max_rank):
    """The pairwise tile kernel == the iterative scatter-min on every
    valid event (invalid events are masked by the insert's validity test
    and may differ)."""
    rng = np.random.default_rng(E)
    key = jnp.asarray(rng.integers(0, n_keys + 1, E).astype(np.int32))
    ra = np.asarray(ew_ops.segment_rank(key, n_keys, max_rank,
                                        impl="scatter"))
    rb = np.asarray(ew_ops.segment_rank(key, n_keys, max_rank,
                                        impl="pallas"))
    valid = np.asarray(key) < n_keys
    np.testing.assert_array_equal(ra[valid], rb[valid])


def test_wheel_generic_insert_rank_impls_agree():
    """The wheel's generic insert produces the identical queue through
    either ranking implementation."""
    rng = np.random.default_rng(5)
    n, E = 12, 40
    tgt = jnp.asarray(rng.integers(0, n, E), jnp.int32)
    t = jnp.asarray(rng.uniform(0, 4, E))
    wa = jnp.asarray(rng.exponential(1.0, E))
    wg = jnp.asarray(rng.exponential(1.0, E))
    valid = jnp.asarray(rng.random(E) < 0.8)
    eqs = [sched.insert(SPEC, sched.make_wheel(n, SPEC), tgt, t, wa, wg,
                        valid, rank_impl=impl)
           for impl in ("scatter", "pallas")]
    for a, b in zip(eqs[0], eqs[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_insert_rows_matches_insert_bitwise():
    """events.insert_rows (the flat batch insert of the compact fan-out)
    is bit-identical to events.insert, including drop accounting."""
    rng = np.random.default_rng(7)
    n, Q = 13, 6
    eq = ev.make_queue(n, Q)
    eq = ev.insert(eq, jnp.asarray(rng.integers(0, n, 10), jnp.int32),
                   jnp.asarray(rng.uniform(0, 5, 10)), jnp.ones(10),
                   jnp.zeros(10), jnp.ones(10, bool))
    for E in (25, 20):
        tgt = jnp.asarray(rng.integers(0, 2 if E == 20 else n, E), jnp.int32)
        t = jnp.asarray(rng.uniform(0, 5, E))
        wa = jnp.asarray(rng.uniform(0, 1, E))
        wg = jnp.asarray(rng.uniform(0, 1, E))
        valid = jnp.asarray(rng.random(E) < 0.85)
        a = ev.insert(eq, tgt, t, wa, wg, valid)
        b = ev.insert_rows(eq, tgt, t, wa, wg, valid)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        eq = a
    assert int(eq.dropped) > 0        # the second batch forced overflow


def test_insert_rows_jaxpr_touches_no_slot_argsort():
    """insert_rows may sort the (small) event batch but must not argsort
    the [N, Q] slot plane: no sort over an N-sized operand."""
    n, Q, E = 64, 8, 12
    eq = ev.make_queue(n, Q)
    args = (jnp.zeros((E,), jnp.int32), jnp.zeros((E,)), jnp.zeros((E,)),
            jnp.zeros((E,)), jnp.ones((E,), bool))
    jaxpr = jax.make_jaxpr(ev.insert_rows)(eq, *args)
    for eqn in jaxpr.jaxpr.eqns:
        if eqn.primitive.name == "sort":
            for v in eqn.invars:
                shape = getattr(v.aval, "shape", ())
                assert not shape or shape[0] <= E, v.aval

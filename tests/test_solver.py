"""ISSUE 7: NDF-grade stepping + reuse-don't-rebuild Newton.

  * setup/solve split: ``newton_setup`` + ``newton_solve`` compose to the
    bitwise-identical result of the fused ``solve_newton_mat`` (both
    preconditioner modes), and the underlying ``hines_factor`` +
    ``hines_solve_factored`` pair matches the fused ``hines_solve`` on
    every morphology — same floating-point op sequence, not just close,
  * NDF error constants: same physics as BDF on the stiff HH burst (spike
    count and phase vs a 1 us cnexp reference) in fewer accepted steps,
  * Jacobian-freshness policy: the default ``jac_policy="reuse"`` performs
    far fewer setups than Newton iterations, rebuilds on forced gamma
    drift / a raised ``jbad`` flag, and the legacy ``"iteration"`` knob
    still pays one setup per iteration and reproduces the pre-PR spike
    trains event-for-event (golden identity matrix),
  * the BDF1-restart rhs in the attempt body is gated behind ``lax.cond``
    (jaxpr-level: no rhs outside the Newton loop / the force conds),
  * new ``BDFState`` fields round-trip through ``repro.checkpoint``,
  * ``auto_spike_cap`` picks sane caps from spike telemetry,
  * the wheel batch insert ranks in the dense [E] batch domain: identical
    ranks and queue contents, no O(N*B) key table in the lowering.
"""
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bdf, exec_common as xc, exec_fap, morphology, network
from repro.core.cell import CellModel
from repro.core.fixed_step import run_fixed
from repro.core.hines import (hines_assemble, hines_factor, hines_solve,
                              hines_solve_factored)
from repro.core.topology import TopologyConfig

N, K, T_END = 16, 4, 8.0
TOPOS = {
    "uniform": "uniform",
    "block": TopologyConfig("block", n_blocks=4, p_in=0.9),
    "ring": TopologyConfig("ring", sigma=3.0),
    "grid2d": TopologyConfig("grid2d", n_blocks=4, sigma=2.0),
    "smallworld": TopologyConfig("smallworld", p_rewire=0.1),
}


@pytest.fixture(scope="module")
def soma():
    return CellModel(morphology.soma_only())


@pytest.fixture(scope="module")
def branched():
    return CellModel(morphology.branched_tree(depth=2, seg_per_branch=3))


@pytest.fixture(scope="module")
def iinj_net():
    rng = np.random.default_rng(1)
    return 0.16 + 0.004 * rng.standard_normal(N)


def _spike_times(ts, vs, thr=-20.0):
    out = []
    for i in range(1, len(ts)):
        if vs[i - 1] <= thr < vs[i]:
            f = (thr - vs[i - 1]) / (vs[i] - vs[i - 1])
            out.append(ts[i - 1] + f * (ts[i] - ts[i - 1]))
    return np.array(out)


def _trace(model, iinj, T, opts):
    st = bdf.reinit(model, 0.0, model.init_state(), iinj, opts)
    stepf = jax.jit(lambda s: bdf.step(model, s, T, iinj, opts))
    ts, vs = [0.0], [float(st.zn[0][model.idx_vsoma])]
    while float(st.t) < T:
        st = stepf(st)
        assert not bool(st.failed)
        ts.append(float(st.t))
        vs.append(float(st.zn[0][model.idx_vsoma]))
    return np.array(ts), np.array(vs), st


# ---------------------------------------------------------------------------
# setup/solve split: bitwise composition + dense oracle
# ---------------------------------------------------------------------------
MORPHS = {
    "soma": morphology.soma_only(),
    "ball_and_stick": morphology.ball_and_stick(n_dend=7),
    "branched2": morphology.branched_tree(depth=2, seg_per_branch=2),
    "branched3": morphology.branched_tree(depth=3, seg_per_branch=3),
}


@pytest.mark.parametrize("name", sorted(MORPHS))
def test_hines_factor_solve_bitwise_matches_fused(name):
    """factor + factored-solve is the fused solve with the d-elimination
    hoisted out — the op sequence applied to b is identical, so the split
    must be bitwise-equal, which is what lets the reuse policy swap it in
    without perturbing trajectories."""
    m = MORPHS[name]
    parent, gax = jnp.asarray(m.parent), jnp.asarray(m.g_axial)
    key = jax.random.PRNGKey(hash(name) % 2**31)
    diag_extra = jax.random.uniform(key, (m.n_comp,)) + 0.5
    b = jax.random.normal(key, (m.n_comp,))
    d = hines_assemble(parent, gax, diag_extra)
    d_elim = hines_factor(parent, gax, d)
    x_split = hines_solve_factored(parent, gax, d_elim, b)
    x_fused = hines_solve(parent, gax, d, b)
    assert np.array_equal(np.asarray(x_split), np.asarray(x_fused))


@pytest.mark.parametrize("mode", ["neuron", "schur"])
def test_newton_setup_solve_matches_fused(soma, branched, mode):
    """setup + factored-solve vs the fused per-iteration rebuild: same
    linear system, so agreement to rounding (the fused path folds gamma
    in a slightly different op order — ULP-level, not bitwise; bitwise
    identity is only claimed for the legacy policy against itself)."""
    for model in (soma, branched):
        rng = np.random.default_rng(model.n_state)
        y = jnp.asarray(np.asarray(model.init_state())
                        + 0.01 * rng.standard_normal(model.n_state))
        b = jnp.asarray(rng.standard_normal(model.n_state))
        for gamma in (0.001, 0.02, 0.3):
            factors = model.newton_setup(y, gamma, mode=mode)
            assert factors.shape == (model.n_factors(mode),)
            x_split = model.newton_solve(factors, b, mode=mode)
            x_fused = model.solve_newton_mat(y, gamma, b, mode=mode)
            np.testing.assert_allclose(np.asarray(x_split),
                                       np.asarray(x_fused),
                                       rtol=1e-10, atol=1e-12)


def test_newton_split_matches_dense_oracle_on_burst_states(soma):
    """Along the stiff burst trajectory the schur split must still solve
    (I - gamma J) exactly against the dense-Jacobian oracle."""
    ts, vs, st = _trace(soma, 0.15, 20.0, bdf.BDFOptions(atol=1e-3))
    y = st.zn[0]
    gamma = 0.02
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(soma.n_state))
    x = soma.newton_solve(soma.newton_setup(y, gamma, mode="schur"), b,
                          mode="schur")
    M = jnp.eye(soma.n_state) - gamma * soma.dense_jacobian(0.0, y)
    assert float(jnp.abs(M @ x - b).max()) < 1e-8


# ---------------------------------------------------------------------------
# NDF vs BDF: same physics, fewer accepted steps
# ---------------------------------------------------------------------------
def test_ndf_parity_fewer_steps_on_stiff_burst(soma):
    T, iinj = 60.0, 0.15
    _, ns, tr = run_fixed(soma, soma.init_state(), T, iinj,
                          method="cnexp", dt=0.001, record_every=1)
    s_ref = _spike_times(np.arange(1, ns + 1) * 0.001, np.asarray(tr))
    assert len(s_ref) >= 3

    out = {}
    for method in ("bdf", "ndf"):
        ts, vs, st = _trace(soma, iinj, T,
                            bdf.BDFOptions(atol=1e-3, method=method))
        s = _spike_times(ts, vs)
        assert len(s) == len(s_ref), (method, len(s), len(s_ref))
        assert np.abs(s - s_ref).max() < 0.25, method
        out[method] = int(st.nst)
    # the kappa-modified error constants buy a real step reduction at
    # equal tolerance (paper-grade: ~15% on the burst drive)
    assert out["ndf"] < out["bdf"], out


# ---------------------------------------------------------------------------
# Jacobian-freshness policy
# ---------------------------------------------------------------------------
def test_reuse_policy_setups_far_fewer_than_iterations(soma):
    opts = bdf.BDFOptions(atol=1e-3)
    st = bdf.reinit(soma, 0.0, soma.init_state(), 0.15, opts)
    st = jax.jit(lambda s: bdf.advance_to(soma, s, 60.0, 0.15, opts))(st)
    assert not bool(st.failed)
    nni, nsetups = int(st.nni), int(st.nsetups)
    assert 1 <= nsetups < nni
    assert nsetups / nni < 0.5


def test_iteration_policy_one_setup_per_iteration(soma):
    opts = bdf.BDFOptions(atol=1e-3, jac_policy="iteration")
    st = bdf.reinit(soma, 0.0, soma.init_state(), 0.15, opts)
    st = jax.jit(lambda s: bdf.advance_to(soma, s, 60.0, 0.15, opts))(st)
    assert not bool(st.failed)
    assert int(st.nsetups) == int(st.nni) > 0


def _settled_state(model, opts, T=5.0):
    """A mid-run state with a freshly-serviced setup counter baseline:
    MSBP clock reset and factors marked current, so the next step only
    rebuilds if something *we* perturb demands it."""
    st = bdf.reinit(model, 0.0, model.init_state(), 0.15, opts)
    st = jax.jit(lambda s: bdf.advance_to(model, s, T, 0.15, opts))(st)
    assert not bool(st.failed)
    return st._replace(nstlp=st.nst, jbad=jnp.zeros((), bool))


def test_gamma_drift_forces_rebuild(soma):
    opts = bdf.BDFOptions(atol=1e-3)
    st = _settled_state(soma, opts)
    stepf = jax.jit(lambda s: bdf.step(soma, s, 1e9, 0.15, opts))

    # probe the gamma the next attempt will use: the probe step rebuilds
    # (or not), and after any rebuild gamma_saved IS that live gamma
    probe = stepf(st)
    assert not bool(probe.failed)
    live_gamma = probe.gamma_saved

    # anchored at the live gamma the step is rebuild-free...
    anchored = st._replace(gamma_saved=live_gamma)
    out0 = stepf(anchored)
    assert not bool(out0.failed)
    assert int(out0.nsetups) == int(st.nsetups)

    # ...and a forced |gamma/gamma_saved - 1| > DGMAX drift, everything
    # else identical, must trigger the rebuild
    drifted = st._replace(gamma_saved=live_gamma * (1.0 + 2 * bdf.DGMAX))
    out1 = stepf(drifted)
    assert not bool(out1.failed)
    assert int(out1.nsetups) > int(st.nsetups)
    # the rebuild re-anchors gamma_saved at the live gamma
    np.testing.assert_allclose(float(out1.gamma_saved), float(live_gamma))


def test_jbad_flag_forces_rebuild(soma):
    opts = bdf.BDFOptions(atol=1e-3)
    st = _settled_state(soma, opts)
    stepf = jax.jit(lambda s: bdf.step(soma, s, 1e9, 0.15, opts))
    out = stepf(st._replace(jbad=jnp.ones((), bool)))
    assert not bool(out.failed)
    assert int(out.nsetups) > int(st.nsetups)
    assert not bool(out.jbad)


# ---------------------------------------------------------------------------
# gated BDF1-restart rhs: jaxpr-level guarantee
# ---------------------------------------------------------------------------
def _sub_jaxprs(v):
    import jax.extend.core as jc
    if isinstance(v, jc.Jaxpr):
        yield v
    elif hasattr(v, "jaxpr"):
        yield v.jaxpr
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _sub_jaxprs(x)


def _find_first_while(jaxpr):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "while":
            return eqn
        for v in eqn.params.values():
            for s in _sub_jaxprs(v):
                r = _find_first_while(s)
                if r is not None:
                    return r
    return None


def _prims_skipping(jaxpr, skip):
    out = set()

    def walk(j):
        for eqn in j.eqns:
            out.add(eqn.primitive.name)
            if eqn.primitive.name in skip:
                continue
            for v in eqn.params.values():
                for s in _sub_jaxprs(v):
                    walk(s)

    walk(jaxpr)
    return out


@pytest.mark.parametrize("policy", ["reuse", "iteration"])
def test_attempt_body_rhs_is_gated(soma, policy):
    """The step-attempt body must not evaluate the HH rhs outside the
    Newton while-loop or a lax.cond: the BDF1-restart rhs (and the reuse
    policy's setup) only exist behind their force conditions.  The HH
    rate functions are the only `exp` users in the stepper, so `exp` at
    the attempt-body top level == a hoisted unconditional rhs."""
    opts = bdf.BDFOptions(atol=1e-3, jac_policy=policy)
    st = bdf.reinit(soma, 0.0, soma.init_state(), 0.15, opts)
    closed = jax.make_jaxpr(lambda s: bdf.step(soma, s, 10.0, 0.15, opts))(st)
    attempt = _find_first_while(closed.jaxpr)
    assert attempt is not None
    body = attempt.params["body_jaxpr"].jaxpr
    top = _prims_skipping(body, skip={"cond", "while"})
    assert "exp" not in top, sorted(top)
    # sanity: the rhs genuinely lives in this body (inside cond/while)
    assert "exp" in _prims_skipping(body, skip=set())


# ---------------------------------------------------------------------------
# golden identity matrix: legacy path == pre-PR spike trains
# ---------------------------------------------------------------------------
_GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                       "golden_spike_trains.npz")


@pytest.mark.parametrize("queue", ["dense", "wheel"])
@pytest.mark.parametrize("topo", sorted(TOPOS))
def test_golden_identity_iteration_policy(soma, iinj_net, topo, queue):
    """``jac_policy="iteration"`` is the pre-PR solver bit-for-bit: the
    recorded spike trains (times, counts, final state, event counts) of
    the seed revision must reproduce exactly on every topology x queue."""
    gold = np.load(_GOLDEN)
    net = network.make_network(N, k_in=K, seed=3, topology=TOPOS[topo])
    opts = bdf.BDFOptions(jac_policy="iteration")
    res, _ = exec_fap.make_fap_vardt_runner(soma, net, iinj_net, T_END,
                                            queue=queue, opts=opts)()
    key = f"{topo}__{queue}"
    assert not bool(res.failed)
    assert np.array_equal(np.asarray(res.rec.times), gold[f"{key}__times"])
    assert np.array_equal(np.asarray(res.rec.count), gold[f"{key}__count"])
    assert np.array_equal(np.asarray(res.y_final), gold[f"{key}__y_final"])
    assert int(res.n_events) == int(gold[f"{key}__n_events"])


def test_reuse_policy_same_physics_on_network(soma, iinj_net):
    """The default policy is allowed to differ bitwise (different Newton
    increments) but must keep the same spike train to scheduler
    tolerance — and actually reuse factors across the run."""
    net = network.make_network(N, k_in=K, seed=3)
    r_it, _ = exec_fap.make_fap_vardt_runner(
        soma, net, iinj_net, T_END,
        opts=bdf.BDFOptions(jac_policy="iteration"))()
    r_re, _ = exec_fap.make_fap_vardt_runner(
        soma, net, iinj_net, T_END, opts=bdf.BDFOptions())()
    assert not bool(r_re.failed)
    c_it, c_re = np.asarray(r_it.rec.count), np.asarray(r_re.rec.count)
    assert np.array_equal(c_it, c_re)
    t_it, t_re = np.asarray(r_it.rec.times), np.asarray(r_re.rec.times)
    for i in range(N):
        a = np.sort(t_it[i][: c_it[i]])
        b = np.sort(t_re[i][: c_re[i]])
        assert np.abs(a - b).max(initial=0.0) < 0.25
    sv = r_re.solver
    assert 0 < int(sv["nsetups"]) < int(sv["nni"])


# ---------------------------------------------------------------------------
# checkpoint round-trip of the new BDFState fields
# ---------------------------------------------------------------------------
def test_bdfstate_new_fields_roundtrip_checkpoint(soma, tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    opts = bdf.BDFOptions(atol=1e-3)
    st = bdf.reinit(soma, 0.0, soma.init_state(), 0.15, opts)
    st = jax.jit(lambda s: bdf.advance_to(soma, s, 10.0, 0.15, opts))(st)
    assert int(st.nsetups) > 0
    save_checkpoint(str(tmp_path), 7, st)
    st2, _ = restore_checkpoint(str(tmp_path), 7, st)
    for name, a, b in zip(st._fields, st, st2):
        assert np.array_equal(np.asarray(a), np.asarray(b)), name
    # the restored factor cache is live: stepping continues without reinit
    out = jax.jit(lambda s: bdf.step(soma, s, 1e9, 0.15, opts))(st2)
    assert not bool(out.failed)
    assert float(out.t) > float(st.t)


# ---------------------------------------------------------------------------
# auto_spike_cap telemetry sizing
# ---------------------------------------------------------------------------
def test_auto_spike_cap_from_telemetry():
    def fake(count_sum, rounds, n=1024, **kw):
        rec = types.SimpleNamespace(count=np.asarray([count_sum]))
        stats = types.SimpleNamespace(rounds=np.asarray(rounds))
        return xc.auto_spike_cap(rec, stats, n, **kw)

    # mean 4 spikes/round * slack 4 = 16 -> exactly the floor
    assert fake(40, 10) == 16
    # mean 20 * 4 = 80 -> next pow2 = 128
    assert fake(200, 10) == 128
    # quiet run: floor wins
    assert fake(0, 10) == 16
    assert fake(1, 1000) == 16
    # cap never exceeds n
    assert fake(10_000, 1, n=64) == 64
    # custom slack/floor honored
    assert fake(40, 10, slack=1.0, floor=2) == 4
    # zero recorded rounds must not divide by zero
    assert fake(5, 0) >= 16


# ---------------------------------------------------------------------------
# wheel batch insert: dense [E] rank domain
# ---------------------------------------------------------------------------
def test_segment_rank_batch_domain_matches_global():
    from repro.kernels.event_wheel import ops as ew_ops

    rng = np.random.default_rng(0)
    n_keys, E, S = 16 * 64, 48, 4           # N*B global domain, E-batch
    for trial in range(5):
        key = rng.integers(0, n_keys, E).astype(np.int32)
        key[rng.random(E) < 0.3] = n_keys   # invalid (parked) events
        k = jnp.asarray(key)
        r_g = np.asarray(ew_ops.segment_rank(k, n_keys, S, impl="scatter"))
        r_b = np.asarray(ew_ops.segment_rank(k, n_keys, S, impl="scatter",
                                             domain="batch"))
        valid = key < n_keys
        assert np.array_equal(r_g[valid], r_b[valid]), trial
        assert np.all(r_b[~valid] == S)


def test_wheel_batch_insert_identical_and_table_free():
    """The wheel queue produced through the batch rank domain is identical
    to the global-domain insert, and its jaxpr allocates no O(N*B) key
    table — the PR 5 follow-up the compact fan-out needed off-TPU."""
    from repro.sched import wheel as wh

    n, E = 512, 24
    spec = wh.WheelSpec()
    B = spec.n_buckets
    rng = np.random.default_rng(3)
    eq = wh.make_wheel(n, spec)
    target = jnp.asarray(rng.integers(0, n, E).astype(np.int32))
    t_ev = jnp.asarray(rng.uniform(0.0, spec.bucket_width * B, E))
    wa = jnp.asarray(rng.random(E))
    wg = jnp.asarray(rng.random(E))
    valid = jnp.asarray(rng.random(E) < 0.8)

    q_g = wh.insert(spec, eq, target, t_ev, wa, wg, valid,
                    rank_impl="scatter")
    q_b = wh.insert(spec, eq, target, t_ev, wa, wg, valid,
                    rank_impl="scatter", rank_domain="batch")
    for a, b in zip(q_g, q_b):
        assert np.array_equal(np.asarray(a), np.asarray(b))

    def shapes(fn, *args):
        closed = jax.make_jaxpr(fn)(*args)
        out = set()

        def walk(j):
            for eqn in j.eqns:
                for v in eqn.outvars:
                    if hasattr(v.aval, "shape"):
                        out.add(tuple(v.aval.shape))
                for p in eqn.params.values():
                    for s in _sub_jaxprs(p):
                        walk(s)

        walk(closed.jaxpr)
        return out

    table = (n * B + 1,)
    ins = lambda dom: (lambda q, tg, t, a, g, v: wh.insert(
        spec, q, tg, t, a, g, v, rank_impl="scatter", rank_domain=dom))
    assert table in shapes(ins("global"), eq, target, t_ev, wa, wg, valid)
    assert table not in shapes(ins("batch"), eq, target, t_ev, wa, wg, valid)

"""Active-set compaction (ISSUE 4): the ``batch="compact"`` execution path
steps only the runnable frontier — compact -> gather -> advance -> scatter —
and must be *event-for-event identical* to the dense path:

  * FAP vardt: identical on all five topologies x both queue impls when the
    frontier fits ``batch_cap``; a forced overflow rolls work to later
    rounds (more rounds, zero drops, same physics to scheduler tolerance),
  * BSP vardt: identical at ANY cap (window chunks share the barrier
    horizon, so chunking never changes a lane's step sequence),
  * the gather-id compaction kernel matches its jnp oracle,
  * SchedStats telemetry rides RunResult and accounts every lane.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exec_bsp, exec_common as xc, exec_fap
from repro.core import morphology, network
from repro.core.cell import CellModel
from repro.core.topology import TOPOLOGIES, TopologyConfig
from repro.kernels.event_wheel import ops as ew_ops
from repro.kernels.event_wheel import ref as ew_ref

N, K, T_END = 16, 4, 8.0       # square N: grid2d needs one

TOPOS = {
    "uniform": "uniform",
    "block": TopologyConfig("block", n_blocks=4, p_in=0.9),
    "ring": TopologyConfig("ring", sigma=3.0),
    "grid2d": TopologyConfig("grid2d", n_blocks=4, sigma=2.0),
    "smallworld": TopologyConfig("smallworld", p_rewire=0.1),
}


@pytest.fixture(scope="module")
def model():
    return CellModel(morphology.soma_only())


@pytest.fixture(scope="module")
def iinj():
    rng = np.random.default_rng(1)
    return 0.16 + 0.004 * rng.standard_normal(N)


def _exact_same(a, b):
    assert np.array_equal(np.asarray(a.rec.times), np.asarray(b.rec.times))
    assert np.array_equal(np.asarray(a.rec.count), np.asarray(b.rec.count))
    assert np.array_equal(np.asarray(a.y_final), np.asarray(b.y_final))
    assert int(a.n_events) == int(b.n_events)
    assert int(a.dropped) == int(b.dropped) == 0
    assert not bool(a.failed) and not bool(b.failed)


def _trains(res):
    ts, c = np.asarray(res.rec.times), np.asarray(res.rec.count)
    return [np.sort(ts[i][: c[i]]) for i in range(len(c))]


# ---------------------------------------------------------------------------
# gather-id compaction kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,cap", [(16, 4), (64, 16), (300, 32), (256, 300)])
def test_compact_ids_pallas_matches_ref(n, cap):
    rng = np.random.default_rng(n + cap)
    for frac in (0.0, 0.1, 0.5, 1.0):
        mask = jnp.asarray(rng.random(n) < frac)
        ia, ca = ew_ref.compact_ids_ref(mask, cap)
        ib, cb = ew_ops.compact_ids(mask, cap, impl="pallas")
        assert int(ca) == int(cb) == int(mask.sum())
        assert np.array_equal(np.asarray(ia), np.asarray(ib))
        # ids are the first `cap` set lanes in index order, sentinel-padded
        want = np.flatnonzero(np.asarray(mask))[:cap]
        got = np.asarray(ia)
        assert np.array_equal(got[: len(want)], want)
        assert np.all(got[len(want):] == n)


def test_select_active_keeps_frontier_when_under_cap():
    rng = np.random.default_rng(0)
    t = jnp.asarray(rng.uniform(0.0, 5.0, 64))
    runnable = jnp.asarray(rng.random(64) < 0.3)
    sel = xc.select_active(runnable, t, 48)
    assert np.array_equal(np.asarray(sel), np.asarray(runnable))


def test_select_active_overflow_keeps_earliest():
    rng = np.random.default_rng(2)
    t = jnp.asarray(rng.uniform(0.0, 5.0, 64))
    runnable = jnp.ones((64,), bool)
    sel = np.asarray(xc.select_active(runnable, t, 8))
    assert 8 <= sel.sum() <= 9            # ties within bisection resolution
    # kept clocks are exactly the smallest ones
    kept = np.sort(np.asarray(t)[sel])
    assert np.all(kept[:8] == np.sort(np.asarray(t))[:8])


# ---------------------------------------------------------------------------
# FAP vardt: compact == dense event-for-event
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("topo", sorted(TOPOS))
@pytest.mark.parametrize("queue", ["dense", "wheel"])
def test_fap_compact_equals_dense(model, iinj, topo, queue):
    """Both compact knobs at once (ISSUE 4 batch + ISSUE 5 fan-out): the
    fully activity-proportional round == the dense round, event for
    event, on every topology x queue."""
    assert set(TOPOS) == set(TOPOLOGIES)
    net = network.make_network(N, k_in=K, seed=3, topology=TOPOS[topo])
    kw = dict(queue=queue)
    r_d, rounds_d = exec_fap.make_fap_vardt_runner(
        model, net, iinj, T_END, **kw)()
    r_c, rounds_c = exec_fap.make_fap_vardt_runner(
        model, net, iinj, T_END, batch="compact", fanout="compact", **kw)()
    assert int(r_d.rec.count.sum()) > 0        # network actually active
    _exact_same(r_d, r_c)
    assert int(rounds_d) == int(rounds_c)


def test_fap_compact_overflow_rolls_not_drops(model, iinj):
    """batch_cap far below the frontier: every round advances only the
    earliest lanes, overflow lanes roll to later rounds — no event is ever
    dropped and the physics stays within the scheduler-restriction
    tolerance of the k_select tests (different horizon sequences)."""
    net = network.make_network(N, k_in=K, seed=3)
    r_d, rounds_d = exec_fap.make_fap_vardt_runner(model, net, iinj, T_END)()
    r_c, rounds_c = exec_fap.make_fap_vardt_runner(
        model, net, iinj, T_END, batch="compact", batch_cap=4)()
    assert int(r_c.dropped) == 0
    assert int(r_c.rec.overflow) == 0
    assert not bool(r_c.failed)
    assert int(rounds_c) > int(rounds_d)       # work genuinely rolled
    td, tc = _trains(r_d), _trains(r_c)
    mismatched = sum(len(a) != len(b) for a, b in zip(td, tc))
    assert mismatched <= 1
    for a, b in zip(td, tc):
        if len(a) == len(b) and len(a):
            assert np.abs(a - b).max() < 0.25
    # occupancy telemetry: the capped batch is nearly always full
    m = xc.sched_metrics(r_c.sched)
    assert m["occupancy"] > 0.9
    assert int(r_c.sched.stepped) <= int(r_c.sched.lanes)


def test_fap_compact_composes_with_other_knobs(model, iinj):
    """compact x wheel queue x fused horizon x threshold k_select: the full
    sort-free stack stays event-for-event identical to its dense twin."""
    net = network.make_network(N, k_in=K, seed=3)
    kw = dict(queue="wheel", horizon_impl="fused", select="threshold",
              k_select=12)
    r_d, _ = exec_fap.make_fap_vardt_runner(model, net, iinj, T_END, **kw)()
    r_c, _ = exec_fap.make_fap_vardt_runner(
        model, net, iinj, T_END, batch="compact", **kw)()
    _exact_same(r_d, r_c)


def test_fap_dense_telemetry_measures_wasted_lanes(model, iinj):
    """The dense path dispatches N lanes per round; telemetry must report
    the wasted fraction the compact path exists to remove."""
    net = network.make_network(N, k_in=K, seed=3)
    r_d, rounds = exec_fap.make_fap_vardt_runner(model, net, iinj, T_END)()
    s = r_d.sched
    assert int(s.rounds) == int(rounds)
    assert int(s.lanes) == N * int(rounds)
    assert 0 <= int(s.stepped) <= int(s.lanes)
    assert int(s.runnable) == int(s.stepped)   # dense: all runnable step
    m = xc.sched_metrics(s)
    assert 0.0 <= m["wasted_lane_frac"] <= 1.0


# ---------------------------------------------------------------------------
# BSP vardt: compact == dense at ANY cap (chunks share the barrier horizon)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cap", [0, 5, 16])
def test_bsp_compact_identical_any_cap(model, iinj, cap):
    net = network.make_network(N, k_in=K, seed=3)
    r_d = exec_bsp.run_bsp_vardt(model, net, iinj, T_END)
    r_c = exec_bsp.run_bsp_vardt(model, net, iinj, T_END, batch="compact",
                                 batch_cap=cap)
    assert int(r_d.rec.count.sum()) > 0
    _exact_same(r_d, r_c)
    # dispatch accounting: every behind-barrier lane stepped exactly once
    assert int(r_c.sched.stepped) == int(r_c.sched.runnable)


def test_bsp_compact_wheel_queue(model, iinj):
    net = network.make_network(N, k_in=K, seed=3)
    r_d = exec_bsp.run_bsp_vardt(model, net, iinj, T_END, queue="wheel")
    r_c = exec_bsp.run_bsp_vardt(model, net, iinj, T_END, queue="wheel",
                                 batch="compact", batch_cap=7)
    _exact_same(r_d, r_c)


def test_unknown_batch_mode_rejected(model, iinj):
    net = network.make_network(N, k_in=K, seed=3)
    with pytest.raises(ValueError, match="batch"):
        exec_fap.make_fap_vardt_runner(model, net, iinj, T_END, batch="x")
    with pytest.raises(ValueError, match="batch"):
        exec_bsp.make_bsp_vardt_runner(model, net, iinj, T_END, batch="x")
    with pytest.raises(ValueError, match="fanout"):
        exec_fap.make_fap_vardt_runner(model, net, iinj, T_END, fanout="x")
    with pytest.raises(ValueError, match="fanout"):
        exec_bsp.make_bsp_vardt_runner(model, net, iinj, T_END, fanout="x")


# ---------------------------------------------------------------------------
# compact fan-out (ISSUE 5): bursty-regime identity incl. the
# spike_cap-overflow fallback path — overflow falls back, never drops
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("queue", ["dense", "wheel"])
def test_fanout_compact_burst_identity(model, queue):
    """Forced synchronized burst: every neuron driven well above
    threshold fires nearly every round.  Compact fan-out must equal the
    dense fan-out event for event both when the spiking set fits
    spike_cap (compact branch) and when it overflows every round
    (spike_cap=1 -> dense fallback branch); nothing may ever drop."""
    net = network.make_network(N, k_in=K, seed=3)
    iinj_burst = np.full(N, 0.22)              # strong DC: all neurons burst
    kw = dict(queue=queue, ev_cap=128,
              wheel=exec_fap.sched.WheelSpec.auto(net))
    r_d, rounds_d = exec_fap.make_fap_vardt_runner(
        model, net, iinj_burst, T_END, **kw)()
    assert int(r_d.rec.count.sum()) >= N       # genuinely bursty
    for cap in (N, 1):                         # compact branch / fallback
        r_c, rounds_c = exec_fap.make_fap_vardt_runner(
            model, net, iinj_burst, T_END, fanout="compact", spike_cap=cap,
            **kw)()
        _exact_same(r_d, r_c)
        assert int(rounds_d) == int(rounds_c)


def test_fanout_compact_bsp_and_speculative(model, iinj):
    """The fan-out knob is wired through BSP vardt and the speculative
    runner too."""
    from repro.core import exec_speculative
    net = network.make_network(N, k_in=K, seed=3)
    r_d = exec_bsp.run_bsp_vardt(model, net, iinj, T_END)
    r_c = exec_bsp.run_bsp_vardt(model, net, iinj, T_END, fanout="compact",
                                 spike_cap=3)
    _exact_same(r_d, r_c)
    s_d, _, _ = exec_speculative.make_spec_runner(model, net, iinj, T_END)()
    s_c, _, _ = exec_speculative.make_spec_runner(
        model, net, iinj, T_END, fanout="compact", spike_cap=3)()
    _exact_same(s_d, s_c)


def test_batch_cap_auto_picks_from_telemetry(model, iinj):
    """batch_cap="auto" probes the frontier and picks a power-of-two cap
    in [floor, N]; the run stays event-for-event identical to dense."""
    net = network.make_network(N, k_in=K, seed=3)
    r_d, _ = exec_fap.make_fap_vardt_runner(model, net, iinj, T_END)()
    run = exec_fap.make_fap_vardt_runner(model, net, iinj, T_END,
                                         batch="compact", batch_cap="auto")
    assert isinstance(run.batch_cap, int) and 1 <= run.batch_cap <= N
    r_a, _ = run()
    _exact_same(r_d, r_a)
    # the picker itself: mean frontier * slack, pow2, clipped
    s = xc.SchedStats(jnp.asarray(1000, jnp.int64), jnp.asarray(0, jnp.int64),
                      jnp.asarray(0, jnp.int64), jnp.asarray(10, jnp.int32))
    assert xc.auto_batch_cap(s, 1 << 16) == 256      # 2*100 -> 256
    assert xc.auto_batch_cap(s, 64) == 64            # clipped at n
    assert xc.auto_batch_cap(xc.SchedStats.zeros(), 1 << 16) == 32


# ---------------------------------------------------------------------------
# the compact round's jaxpr stays sort-free with the sort-free knob stack
# ---------------------------------------------------------------------------
def test_compact_round_jaxpr_sort_free(model, iinj):
    from repro import sched
    net = network.make_network(N, k_in=K, seed=3)
    run = exec_fap.make_fap_vardt_runner(
        model, net, iinj, T_END, batch="compact", batch_cap=8,
        queue="wheel", horizon_impl="fused", select="threshold")
    carry = run.init_carry()
    prims = sched.jaxpr_primitives(run.round_body, carry)
    assert "sort" not in prims

"""Preemption tolerance on the SPMD driver (4-device host-platform mesh,
subprocess — jax device count locks at first init).

Acceptance (ISSUE 9): a ``run_fap_spmd`` run checkpointed every k rounds,
killed mid-run via ``SimulatedFailure`` and resumed produces a spike
train bit-identical to the uninterrupted run, across >= 2 topologies x
both queue implementations; an injected non-finite lane is detected by
the watchdog, rolled back and reported on ``RunResult.health`` — never
silently propagated; elastic resume onto a different mesh shape reseeds
the shard-shaped horizon carry and stays bit-identical; parcel-cap drops
escalate onto health.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.checkpoint import FaultPlan, SimulatedFailure
from repro.core import morphology, network
from repro.core.cell import CellModel
from repro.core.topology import TopologyConfig
from repro.distributed.exchange import ExchangeSpec
from repro.distributed.fap_spmd import run_fap_spmd
from repro.launch.mesh import make_mesh_compat

mesh = make_mesh_compat((2, 2), ("data", "model"))
model = CellModel(morphology.soma_only())
n = 16
net_u = network.make_network(n, k_in=4, seed=3)
net_b = network.make_network(n, k_in=4, seed=3,
                             topology=TopologyConfig("block", n_blocks=4,
                                                     p_in=0.95))
rng = np.random.default_rng(1)
iinj = 0.16 + 0.004 * rng.standard_normal(n)
T = 6.0
out = {}


def ident(a, b):
    return (bool(np.array_equal(np.asarray(a.rec.times),
                                np.asarray(b.rec.times)))
            and bool(np.array_equal(np.asarray(a.rec.count),
                                    np.asarray(b.rec.count))))


def kill_resume(tag, netx, mesh2=None, **kw):
    # baseline -> kill at ~60% of the rounds -> resume (optionally on a
    # different mesh); report identity + health
    base, r0 = run_fap_spmd(model, netx, iinj, T, mesh, max_rounds=80, **kw)
    d = tempfile.mkdtemp()
    kill = max(2, int(r0 * 0.6))
    every = 5
    try:
        run_fap_spmd(model, netx, iinj, T, mesh, max_rounds=80,
                     checkpoint_every=every, ckpt_dir=d,
                     fault=FaultPlan(fail_at_round=kill), **kw)
        raise RuntimeError("SimulatedFailure did not fire")
    except SimulatedFailure:
        pass
    res, r1 = run_fap_spmd(model, netx, iinj, T, mesh2 or mesh,
                           max_rounds=80, ckpt_dir=d, resume=True, **kw)
    out[tag] = {
        "identical": ident(base, res), "rounds": [r0, r1],
        "spikes": int(np.asarray(base.rec.count).sum()),
        "dropped": int(res.dropped), "failed": bool(res.failed),
        "resumed_from": res.health["resumed_from"],
        "elastic_reseeded": res.health["elastic_reseeded"],
        "checks": res.health["checks"],
    }
    return base


sp = dict(optimized=True, transport="sparse",
          exchange=ExchangeSpec(parcel_cap=8))
spw = dict(optimized=True, transport="sparse", queue="wheel",
           exchange=ExchangeSpec(parcel_cap=8, compact_impl="jnp"))
base_ud = kill_resume("uniform/dense", net_u, **sp)
kill_resume("uniform/wheel", net_u, **spw)
kill_resume("block/dense", net_b, **sp)
kill_resume("block/wheel", net_b, **spw)

# elastic resume: kill on the (2,2) mesh, resume on (4,1) — the
# incremental-horizon carry is shard-relative and must be reseeded
mesh41 = make_mesh_compat((4, 1), ("data", "model"))
kill_resume("elastic", net_u, mesh2=mesh41, batch="compact", batch_cap=8,
            horizon="incremental", **sp)

# watchdog: poison one lane's BDF history mid-run -> detected the same
# round, rolled back to the last checkpoint, completed identically
d = tempfile.mkdtemp()
res_p, _ = run_fap_spmd(model, net_u, iinj, T, mesh, max_rounds=80,
                        checkpoint_every=5, ckpt_dir=d,
                        fault=FaultPlan(poison_at_round=12, poison_lane=5),
                        **sp)
out["poison"] = {
    "identical": ident(base_ud, res_p), "failed": bool(res_p.failed),
    "nonfinite_rounds": res_p.health["nonfinite_rounds"],
    "rollbacks": res_p.health["rollbacks"],
    "rollback_exhausted": res_p.health["rollback_exhausted"],
}

# injected parcel-cap overflow: hot network + cap=1 -> the drop counter
# fires AND is escalated onto RunResult.health (detected, never silent)
iinj_hot = 0.20 + 0.004 * rng.standard_normal(n)
res_of, _ = run_fap_spmd(model, net_u, iinj_hot, T, mesh, max_rounds=80,
                         transport="sparse",
                         exchange=ExchangeSpec(parcel_cap=1))
out["overflow"] = {"dropped": int(res_of.dropped),
                   "health_dropped": res_of.health["dropped_events"]}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def rob_out():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=ROOT)
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


pytestmark = pytest.mark.slow

MATRIX = ["uniform/dense", "uniform/wheel", "block/dense", "block/wheel"]


@pytest.mark.parametrize("tag", MATRIX)
def test_kill_resume_bit_identical(rob_out, tag):
    """Acceptance: kill/resume spike-train identity across 2 topologies x
    both queue implementations."""
    r = rob_out[tag]
    assert r["spikes"] > 0
    assert r["identical"], r
    assert r["rounds"][0] == r["rounds"][1]
    assert r["dropped"] == 0 and not r["failed"]
    assert r["resumed_from"] is not None and r["resumed_from"] > 0


@pytest.mark.parametrize("tag", MATRIX)
def test_watchdog_ran_every_round(rob_out, tag):
    """The resumed leg's watchdog checked every round it drove."""
    r = rob_out[tag]
    assert r["checks"] == r["rounds"][1] - r["resumed_from"]


def test_elastic_mesh_resume(rob_out):
    """Resume onto a different mesh shape reseeds the shard-relative
    horizon carry (fingerprint mismatch) and stays bit-identical."""
    r = rob_out["elastic"]
    assert r["identical"], r
    assert r["elastic_reseeded"]
    assert r["dropped"] == 0 and not r["failed"]


def test_poison_detected_rolled_back_never_silent(rob_out):
    """Acceptance: the injected non-finite lane is detected by the health
    watchdog, rolled back, reported on RunResult.health, and the
    completed run is bit-identical — never silently propagated."""
    p = rob_out["poison"]
    assert p["nonfinite_rounds"] >= 1
    assert p["rollbacks"] >= 1
    assert not p["rollback_exhausted"] and not p["failed"]
    assert p["identical"], p


def test_parcel_drops_escalate_to_health(rob_out):
    """Queue/parcel overflow rides RunResult.health, not only the raw
    dropped counter."""
    o = rob_out["overflow"]
    assert o["dropped"] > 0
    assert o["health_dropped"] == o["dropped"]

"""Locality-aware placement (repro.distributed.placement): permutation
algebra, edge-cut descent, frontier shrink through the transport's own
tables, and the round-trip guarantee — a placement applied before the run
and inverted on outputs is event-for-event invisible to every execution
model."""
import numpy as np
import pytest

from repro import sched
from repro.core import exec_bsp, exec_fap, morphology, network
from repro.core.cell import CellModel
from repro.core.topology import TopologyConfig
from repro.distributed import placement as plc
from repro.distributed.sharding import shard_frontier

N, K, S = 64, 4, 4
TOPOS = {
    "uniform": "uniform",
    "block": TopologyConfig("block", n_blocks=S, p_in=0.95),
    "ring": TopologyConfig("ring", sigma=3.0),
    "grid2d": TopologyConfig("grid2d", sigma=1.5),
    "smallworld": TopologyConfig("smallworld", p_rewire=0.1),
}


def _net(topo, seed=3):
    return network.make_network(N, k_in=K, seed=seed, topology=TOPOS[topo])


def _shuffled(net, seed=5):
    order = np.random.default_rng(seed).permutation(int(net.n))
    return plc.place_network(net, plc.from_order(order, S, net, "shuffle"))


# ---------------------------------------------------------------------------
# permutation algebra + isomorphism
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", ["identity", "block", "greedy"])
def test_perm_is_bijection(method):
    pl = plc.compute_placement(_net("block"), S, method=method)
    assert np.array_equal(pl.perm[pl.inv], np.arange(N))
    assert np.array_equal(pl.inv[pl.perm], np.arange(N))
    assert pl.cut == plc.cut_edges(_net("block").pre, _net("block").post,
                                   N, S, pl.perm)


@pytest.mark.parametrize("topo", list(TOPOS))
def test_place_network_is_isomorphism(topo):
    """Relabeled edges map back exactly to the originals, the grouped
    by-post layout survives, and weights/delays ride along untouched."""
    net = _net(topo)
    pl = plc.compute_placement(net, S, method="auto")
    placed = plc.place_network(net, pl)
    assert sched.grouped_k(placed) == K
    orig = sorted(zip(pl.inv[placed.pre], pl.inv[placed.post],
                      placed.delay, placed.w_ampa, placed.w_gaba))
    base = sorted(zip(net.pre, net.post, net.delay, net.w_ampa, net.w_gaba))
    assert orig == base
    if net.block is not None:
        assert np.array_equal(placed.block, np.asarray(net.block)[pl.inv])


def test_place_network_generic_layout():
    """Non-grouped edge lists relabel through the stable re-sort path."""
    net = _net("block")
    order = np.random.default_rng(0).permutation(N * K)
    scrambled = net._replace(pre=net.pre[order], post=net.post[order],
                             delay=net.delay[order],
                             w_ampa=net.w_ampa[order],
                             w_gaba=net.w_gaba[order])
    assert sched.grouped_k(scrambled) is None
    pl = plc.compute_placement(scrambled, S, method="greedy")
    placed = plc.place_network(scrambled, pl)
    assert np.array_equal(placed.post,
                          np.sort(pl.perm[scrambled.post], kind="stable"))
    orig = sorted(zip(pl.inv[placed.pre], pl.inv[placed.post], placed.delay))
    assert orig == sorted(zip(scrambled.pre, scrambled.post, scrambled.delay))


# ---------------------------------------------------------------------------
# edge-cut descent + frontier shrink
# ---------------------------------------------------------------------------
def test_placement_recovers_shuffled_block_locality():
    """On a label-shuffled block net the contiguous-block pass recovers the
    native cut exactly, and greedy never does worse."""
    net = _net("block")
    native_cut = plc.cut_edges(net.pre, net.post, N, S)
    shuf = _shuffled(net)
    cut_id = plc.compute_placement(shuf, S, "identity").cut
    cut_blk = plc.compute_placement(shuf, S, "block").cut
    cut_gr = plc.compute_placement(shuf, S, "greedy").cut
    assert cut_blk == native_cut
    assert cut_gr <= cut_blk <= cut_id
    assert cut_blk < cut_id / 3


def test_greedy_improves_without_block_metadata():
    """Greedy runs from identity when no block metadata exists and must
    never increase the cut (ring wiring gives it real gradient)."""
    net = _net("ring")._replace(block=None)
    cut_id = plc.cut_edges(net.pre, net.post, N, S)
    pl = plc.compute_placement(net, S, method="greedy")
    assert pl.cut <= cut_id


def test_auto_method_dispatch():
    assert plc.compute_placement(_net("block"), S, "auto").method == "greedy"
    assert plc.compute_placement(_net("uniform"), S,
                                 "auto").method == "identity"
    with pytest.raises(ValueError):
        plc.compute_placement(_net("block"), S, "metis")
    with pytest.raises(ValueError):
        plc.compute_placement(_net("block"), 7)


def test_frontier_shrinks_through_transport_tables():
    """The realized notify frontier — measured via the same shard_frontier
    tables the sparse transport ships — shrinks under placement on the
    shuffled block net and stays ~N on uniform wiring."""
    shuf = _shuffled(_net("block"))
    st_id = plc.frontier_stats(shuf, S)
    pl = plc.compute_placement(shuf, S, "greedy")
    st_pl = plc.frontier_stats(shuf, S, pl)
    assert st_pl["F"] < st_id["F"] / 2
    assert st_pl["cut_edges"] == pl.cut
    st_u = plc.frontier_stats(_net("uniform"), S)
    assert st_u["boundary_frac"] > 0.8
    # perm= on shard_frontier == frontier of the placed net
    placed = plc.place_network(shuf, pl)
    fr_perm = shard_frontier(shuf.pre, shuf.post, N, S, perm=pl.perm)
    fr_placed = shard_frontier(placed.pre, placed.post, N, S)
    assert np.array_equal(fr_perm.boundary_gid, fr_placed.boundary_gid)
    assert np.array_equal(fr_perm.dest_map, fr_placed.dest_map)
    assert np.array_equal(fr_perm.sizes, fr_placed.sizes)


# ---------------------------------------------------------------------------
# round-trip: placement is invisible to the execution models
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def model():
    return CellModel(morphology.soma_only())


def _trains(res):
    ts, c = np.asarray(res.rec.times), np.asarray(res.rec.count)
    return [sorted(float(t) for t in ts[i][: c[i]]) for i in range(len(c))]


def _assert_same_trains(a, b):
    assert len(a) == len(b)
    for ta, tb in zip(a, b):
        assert len(ta) == len(tb)
        if ta:
            assert max(abs(x - y) for x, y in zip(ta, tb)) < 1e-9


@pytest.mark.parametrize("topo", list(TOPOS))
def test_roundtrip_exec_fap_all_topologies(topo, model):
    """Satellite acceptance: permutation applied + inverted yields
    event-for-event identical spike trains vs the unpartitioned exec_fap
    anchor, on every topology."""
    net = _net(topo)
    rng = np.random.default_rng(2)
    iinj = 0.16 + 0.004 * rng.standard_normal(N)
    anchor = exec_fap.run_fap_vardt(model, net, iinj, 5.0)
    pl = plc.compute_placement(net, S, method="auto")
    if np.array_equal(pl.perm, np.arange(N)):
        # identity (uniform auto, or already-contiguous blocks): force a
        # non-trivial permutation so the round-trip is exercised, not vacuous
        pl = plc.from_order(np.random.default_rng(9).permutation(N), S, net)
    assert not np.array_equal(pl.perm, np.arange(N))
    net_p, iinj_p = plc.place_inputs(net, iinj, pl)
    res = exec_fap.run_fap_vardt(model, net_p, iinj_p, 5.0)
    res = plc.unpermute_result(res, pl)
    assert sum(len(t) for t in _trains(anchor)) > 0
    _assert_same_trains(_trains(anchor), _trains(res))
    assert np.allclose(np.asarray(anchor.y_final), np.asarray(res.y_final),
                       atol=1e-9)


def test_roundtrip_other_exec_models(model):
    """The permutation is equally invisible to the BSP and fixed-step FAP
    models — placement touches ids only, never physics."""
    net = _net("block")
    rng = np.random.default_rng(2)
    iinj = 0.16 + 0.004 * rng.standard_normal(N)
    pl = plc.compute_placement(net, S, method="greedy")
    net_p, iinj_p = plc.place_inputs(net, iinj, pl)
    for run in (exec_bsp.run_bsp_fixed, exec_fap.run_fap_fixed):
        anchor = run(model, net, iinj, 4.0)
        res = plc.unpermute_result(run(model, net_p, iinj_p, 4.0), pl)
        _assert_same_trains(_trains(anchor), _trains(res))

"""Structured connectivity generators (repro.core.topology): every topology
preserves make_network's static invariants, the uniform path stays
bit-identical to the pre-knob behaviour, and the structure each generator
claims is *measured* from the emitted block metadata / edge list."""
import numpy as np
import pytest

from repro import sched
from repro.core import network
from repro.core.topology import (TopologyConfig, TOPOLOGIES, as_config,
                                 edge_block_pairs, intra_block_frac,
                                 ring_distance)

N, K = 64, 4


def _net(name, **kw):
    return network.make_network(N, k_in=K, seed=11,
                                topology=TopologyConfig(name, **kw))


def test_uniform_matches_pre_knob_stream():
    """topology="uniform" consumes the identical rng stream as the seed
    make_network, so historical seeded networks are bit-for-bit unchanged."""
    rng = np.random.default_rng(7)
    post = np.repeat(np.arange(N, dtype=np.int32), K)
    pre = rng.integers(0, N, size=N * K).astype(np.int32)
    clash = pre == post
    pre[clash] = (pre[clash] + 1) % N
    delay = network.sample_delays(rng, N * K)
    net = network.make_network(N, k_in=K, seed=7)
    assert np.array_equal(net.pre, pre)
    assert np.allclose(net.delay, delay)
    assert net.block is None
    net2 = network.make_network(N, k_in=K, seed=7, topology="uniform")
    assert np.array_equal(net2.pre, net.pre)


@pytest.mark.parametrize("name", TOPOLOGIES)
def test_static_invariants(name):
    """Grouped by-post layout, uniform in-degree, no self edges, ids in
    range — the invariants every downstream fast path relies on."""
    net = _net(name)
    assert sched.grouped_k(net) == K
    assert net.pre.dtype == np.int32
    assert (net.pre >= 0).all() and (net.pre < N).all()
    assert (net.pre != net.post).all()
    if name == "uniform":
        assert net.block is None
    else:
        assert net.block is not None and net.block.shape == (N,)
        assert net.block.dtype == np.int32


def test_block_locality_measured():
    """In-block edge fraction tracks p_in, and blocks are contiguous tiles."""
    net = _net("block", n_blocks=4, p_in=0.9)
    assert np.array_equal(net.block, np.arange(N) // (N // 4))
    frac = intra_block_frac(net)
    assert 0.8 <= frac <= 1.0
    pairs = edge_block_pairs(net)
    assert pairs.shape == (N * K, 2)
    # cross edges really leave the block (out-of-block sampling never
    # lands back inside)
    lo = _net("block", n_blocks=4, p_in=0.0)
    assert intra_block_frac(lo) < 0.05


def test_ring_distance_falloff():
    """Ring wiring concentrates |pre - post| near sigma; uniform does not."""
    ring = _net("ring", sigma=3.0)
    uni = network.make_network(N, k_in=K, seed=11)
    d_ring = ring_distance(ring)
    d_uni = ring_distance(uni)
    assert (d_ring >= 1).all()
    assert d_ring.mean() < 6.0 < d_uni.mean()


def test_grid2d_offsets_local_and_square_only():
    net = _net("grid2d", sigma=1.0)
    side = 8
    px, py = net.post % side, net.post // side
    qx, qy = net.pre % side, net.pre // side
    dx = np.minimum((px - qx) % side, (qx - px) % side)
    dy = np.minimum((py - qy) % side, (qy - py) % side)
    assert np.hypot(dx, dy).mean() < 2.5
    with pytest.raises(ValueError):
        network.make_network(48, k_in=K, seed=0, topology="grid2d")


def test_smallworld_lattice_and_rewiring():
    """p_rewire=0 is the pure k-nearest ring lattice; the measured rewired
    fraction tracks p_rewire."""
    lat = _net("smallworld", p_rewire=0.0)
    offs = {1, 2, -1, -2}
    d = (lat.pre.astype(np.int64) - lat.post.astype(np.int64)) % N
    d = np.where(d > N // 2, d - N, d)
    assert set(np.unique(d)) <= offs
    sw = _net("smallworld", p_rewire=0.3)
    d2 = (sw.pre.astype(np.int64) - sw.post.astype(np.int64)) % N
    d2 = np.where(d2 > N // 2, d2 - N, d2)
    frac_lattice = np.isin(d2, list(offs)).mean()
    assert 0.55 <= frac_lattice <= 0.9          # ~1 - p_rewire (+ chance hits)


def test_knob_validation():
    with pytest.raises(ValueError):
        network.make_network(N, k_in=K, topology="voronoi")
    with pytest.raises(ValueError):
        network.make_network(N, k_in=K,
                             topology=TopologyConfig("block", n_blocks=7))
    with pytest.raises(TypeError):
        as_config(42)
    with pytest.raises(ValueError):
        intra_block_frac(network.make_network(N, k_in=K))


def test_structured_nets_run_unmodified():
    """A structured net drives the single-host FAP runner like any other
    (grouped insert fast path included) — the knob changes wiring only."""
    from repro.core import exec_fap, morphology
    from repro.core.cell import CellModel

    model = CellModel(morphology.soma_only())
    net = _net("block", n_blocks=4, p_in=0.9)
    rng = np.random.default_rng(1)
    iinj = 0.16 + 0.004 * rng.standard_normal(N)
    res = exec_fap.run_fap_vardt(model, net, iinj, 4.0)
    assert not bool(res.failed)
    assert int(res.dropped) == 0
    assert int(np.asarray(res.rec.count).sum()) > 0

"""Exchange-layer units that need no multi-device mesh: the sort-free
spike-compaction kernel, the static shard-frontier builder, and the
per-channel HLO byte attribution."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched
from repro.distributed.sharding import shard_frontier
from repro.kernels.event_wheel import ops as ew_ops
from repro.launch.hlo_analysis import collective_channel_bytes


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("cap", [4, 16, 64])
def test_compact_pallas_matches_ref(seed, cap):
    """The Pallas cumsum-rank compaction == the jnp scatter oracle, for
    under- and over-full rows."""
    rng = np.random.default_rng(seed)
    D, M = 6, 41
    mask = jnp.asarray(rng.random((D, M)) < 0.4)
    vals = jnp.asarray(rng.uniform(0.0, 10.0, (D, M)))
    i1, v1, c1 = ew_ops.spike_compact(mask, vals, cap, impl="pallas")
    i2, v2, c2 = ew_ops.spike_compact(mask, vals, cap, impl="jnp")
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    # semantic oracle: row d packs the first min(cap, kept) masked columns
    m_np = np.asarray(mask)
    for d in range(D):
        cols = np.flatnonzero(m_np[d])
        assert int(c1[d]) == len(cols)               # count NOT capped
        kept = cols[:cap]
        got = np.asarray(i1[d])
        assert (got[: len(kept)] == kept).all()
        assert (got[len(kept):] == M).all()          # sentinel pads
        np.testing.assert_allclose(np.asarray(v1[d])[: len(kept)],
                                   np.asarray(vals[d])[kept])


def test_compact_jaxpr_sort_free():
    """Acceptance wiring: the spike-parcel packer lowers without any sort
    primitive on either implementation."""
    mask = jnp.asarray(np.random.default_rng(0).random((4, 32)) < 0.3)
    vals = jnp.ones((4, 32))
    for impl in ("pallas", "jnp"):
        prims = sched.jaxpr_primitives(
            lambda m, v: ew_ops.spike_compact(m, v, 8, impl=impl), mask, vals)
        assert "sort" not in prims, (impl, prims)


def test_shard_frontier_tables():
    """Boundary sets and destination map against a brute-force oracle."""
    rng = np.random.default_rng(3)
    n, k, n_shards = 24, 3, 4
    n_local = n // n_shards
    post = np.repeat(np.arange(n, dtype=np.int32), k)
    pre = rng.integers(0, n, n * k).astype(np.int32)
    fr = shard_frontier(pre, post, n, n_shards)
    assert fr.dest_map.shape == (n, n_shards)
    for i in range(n):
        dests = set(post[pre == i] // n_local)
        assert set(np.flatnonzero(fr.dest_map[i])) == dests
    for s in range(n_shards):
        own = (pre // n_local == s)
        cross = own & (post // n_local != s)
        expect = set(pre[cross])
        gids = fr.boundary_gid[s]
        assert set(gids[gids < n]) == expect
        rel = fr.boundary_rel[s][gids < n]
        assert (rel == gids[gids < n] - s * n_local).all()


def test_shard_frontier_rejects_indivisible():
    with pytest.raises(ValueError):
        shard_frontier(np.zeros(4, np.int32), np.zeros(4, np.int32), 10, 4)


FAKE_HLO = """\
ENTRY %main (p: f64[8]) -> f64[8] {
  %ag = f64[64]{0} all-gather(f64[16]{0} %a), channel_id=1, metadata={op_name="jit(f)/shmap/exchange_notify/all_gather" source_file="x.py"}
  %a2a = (s32[1,8]{1,0}, s32[1,8]{1,0}) all-to-all(s32[1,8]{1,0} %b, s32[1,8]{1,0} %c), channel_id=2, metadata={op_name="jit(f)/shmap/exchange_parcel/all_to_all"}
  %ar = f64[4]{0} all-reduce(f64[4]{0} %d), channel_id=3, metadata={op_name="jit(f)/psum"}
  ROOT tuple = (f64[8]) tuple(%p)
}
"""


def test_collective_channel_bytes_attribution():
    """named_scope tags in op_name metadata route collective bytes to their
    channel; untagged collectives land in "other"."""
    got = collective_channel_bytes(FAKE_HLO)
    assert got["exchange_notify"] == 64 * 8
    assert got["exchange_parcel"] == 2 * 8 * 4     # tuple components summed
    assert got["other"] == 4 * 8

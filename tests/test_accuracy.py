"""Paper-claim validation (Fig. 5/6/7 scale-downs as assertions):
  * Euler phase shift grows with dt; CVODE does not accumulate shift,
  * CVODE step count plummets for quiet dynamics (Fig. 6),
  * discontinuity rate degrades CVODE as the paper describes (Fig. 7).
"""
import jax
import numpy as np
import pytest

from repro.core import bdf, morphology
from repro.core.calibrate import threshold_current
from repro.core.cell import CellModel
from repro.core.fixed_step import run_fixed


@pytest.fixture(scope="module")
def model():
    return CellModel(morphology.soma_only())


@pytest.fixture(scope="module")
def i_th(model):
    return threshold_current(model, t_end=100.0)


def _spike_times(ts, vs, thr=-20.0):
    out = []
    for i in range(1, len(ts)):
        if vs[i - 1] <= thr < vs[i]:
            f = (thr - vs[i - 1]) / (vs[i] - vs[i - 1])
            out.append(ts[i - 1] + f * (ts[i] - ts[i - 1]))
    return np.array(out)


def test_euler_phase_shift_grows_with_dt(model, i_th):
    # 3.5x threshold: safely above the type-II repetitive-firing onset
    T, iinj = 150.0, 3.5 * i_th
    curves = {}
    for dt in (0.001, 0.005, 0.025):
        _, ns, tr = run_fixed(model, model.init_state(), T, iinj,
                              method="euler", dt=dt, record_every=1)
        curves[dt] = _spike_times(np.arange(1, ns + 1) * dt, np.asarray(tr))
    ref = curves[0.001]
    n = min(len(ref), len(curves[0.005]), len(curves[0.025]))
    assert n >= 3
    shift5 = np.abs(curves[0.005][:n] - ref[:n]).max()
    shift25 = np.abs(curves[0.025][:n] - ref[:n]).max()
    assert shift25 > shift5                       # paper Fig.5: shift ~ dt
    # and the shift accumulates: last spike shifted more than first
    assert abs(curves[0.025][n - 1] - ref[n - 1]) > abs(curves[0.025][0] - ref[0])


def test_cvode_beats_euler_steps_quiet_vs_active(model, i_th):
    """Fig. 6 trend: the step-count advantage shrinks as stiffness rises."""
    T = 500.0
    n_fixed = int(T / 0.025)
    ratios = {}
    for pct in (0.5, 5.0):
        iinj = pct * i_th
        opts = bdf.BDFOptions(atol=1e-3)
        st = bdf.reinit(model, 0.0, model.init_state(), iinj, opts)
        st = jax.jit(lambda s, ii=iinj: bdf.advance_to(model, s, T, ii, opts))(st)
        assert not bool(st.failed)
        ratios[pct] = n_fixed / int(st.nst)
    assert ratios[0.5] > 100                      # paper: 434x at <50%
    assert ratios[5.0] > 2                        # paper: 9.4x at 500%
    assert ratios[0.5] > ratios[5.0]


def test_discontinuity_rate_controls_cvode_cost(model):
    """Fig. 7 trend: steps grow with event frequency (IVP resets)."""
    T, w = 200.0, 1e-3
    opts = bdf.BDFOptions(atol=1e-3)
    steps = {}
    for freq in (20.0, 200.0):
        period = 1000.0 / freq
        n_ev = int(T / period)
        st = bdf.reinit(model, 0.0, model.init_state(), 0.0, opts)
        adv = jax.jit(lambda s, tl: bdf.advance_to(model, s, tl, 0.0, opts))
        dlv = jax.jit(lambda s: bdf.deliver_event(model, s, w, 0.0, 0.0, opts))
        for k in range(1, n_ev + 1):
            st = adv(st, k * period)
            st = dlv(st)
        st = adv(st, T)
        assert not bool(st.failed)
        steps[freq] = int(st.nst)
    assert steps[200.0] > 2 * steps[20.0]
    assert int(st.nreset) == int(T / (1000.0 / 200.0))


def test_threshold_current_is_calibrated(model, i_th):
    from repro.core.calibrate import _n_spikes
    assert _n_spikes(model, 0.95 * i_th, 100.0) == 0
    assert _n_spikes(model, 1.05 * i_th, 100.0) >= 1

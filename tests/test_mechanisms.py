"""HH mechanism correctness: singularity safety, steady states, units,
and the staggered fixed-step solver family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mechanisms as mech
from repro.core import morphology
from repro.core.cell import CellModel
from repro.core.fixed_step import run_fixed


def test_exprel_singularity_safe():
    xs = jnp.array([-1e-12, 0.0, 1e-12, 1e-3, -1e-3, 5.0, -5.0])
    out = mech.exprel(xs)
    assert np.isfinite(np.asarray(out)).all()
    assert float(mech.exprel(jnp.zeros(()))) == pytest.approx(1.0, abs=1e-9)
    # alpha_m singular point V = -40 mV and alpha_n at V = -55 mV
    for f, v in [(mech.alpha_m, -40.0), (mech.alpha_n, -55.0)]:
        v_arr = jnp.array([v - 1e-7, v, v + 1e-7])
        a = np.asarray(f(v_arr))
        assert np.isfinite(a).all()
        assert abs(a[0] - a[2]) < 1e-5


def test_gate_rates_differentiable_everywhere():
    g = jax.grad(lambda v: mech.alpha_m(v) + mech.alpha_n(v))
    for v in [-80.0, -55.0, -40.0, 0.0, 40.0]:
        assert np.isfinite(float(g(jnp.asarray(v))))


def test_resting_state_is_quasi_stationary():
    m = CellModel(morphology.soma_only())
    y0 = m.init_state(-65.0)
    f = m.rhs(0.0, y0, 0.0)
    # near rest: small drift only (EL != -65 exactly, HH rest ~ -65)
    v_drift = float(jnp.abs(f[0]))
    assert v_drift < 0.5                     # mV/ms
    y, _, _ = run_fixed(m, y0, 50.0, 0.0, method="cnexp", dt=0.025)
    assert abs(float(y[0]) - (-65.0)) < 2.0  # settles near rest


@pytest.mark.parametrize("method", ["cnexp", "euler", "derivimplicit"])
def test_fixed_step_methods_converge_together(method):
    """All three staggered solvers agree as dt -> 0 (same physics)."""
    m = CellModel(morphology.soma_only())
    y0 = m.init_state()
    ref, _, _ = run_fixed(m, y0, 5.0, 0.12, method="cnexp", dt=0.001)
    y, _, _ = run_fixed(m, y0, 5.0, 0.12, method=method, dt=0.005)
    assert abs(float(y[0]) - float(ref[0])) < 1.0


def test_derivimplicit_handles_complex_mechanism():
    """Correlated (ca, rho) pair: implicit per-mechanism Newton stays in
    [0, 1] and decays calcium; explicit euler at the same dt is less
    stable by construction (paper §2.2)."""
    m = CellModel(morphology.soma_only(), with_plasticity=True)
    y0 = m.init_state()
    y0 = m.apply_event(y0, 1e-3, 0.0)              # calcium jump
    y, _, _ = run_fixed(m, y0, 100.0, 0.0, method="derivimplicit", dt=0.025)
    ca, rho = float(y[m.idx_ca]), float(y[m.idx_ca + 1])
    assert 0.0 <= rho <= 1.0
    assert ca < float(y0[m.idx_ca])                # decayed


def test_spike_shape_sane():
    m = CellModel(morphology.soma_only())
    _, _, tr = run_fixed(m, m.init_state(), 20.0, 0.2, method="cnexp",
                         dt=0.025, record_every=1)
    tr = np.asarray(tr)
    assert tr.max() > 20.0                         # overshoot above 0 mV
    assert tr.min() > -90.0                        # AHP bounded
    assert tr.max() < 60.0


def test_synapse_decay_time_constants():
    m = CellModel(morphology.soma_only())
    y = m.apply_event(m.init_state(), 1.0, 1.0)
    y2, _, _ = run_fixed(m, y, mech.TAU_AMPA, 0.0, method="cnexp", dt=0.005)
    g_a = float(y2[m.idx_g_ampa])
    assert g_a == pytest.approx(np.exp(-1.0), rel=0.15)   # one tau elapsed

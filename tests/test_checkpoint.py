"""Checkpoint + fault tolerance: roundtrip, atomicity, integrity, restart,
failure injection, NaN quarantine, elastic resume, data-cursor determinism."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (RestartManager, latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.checkpoint.checkpoint import prune_checkpoints
from repro.checkpoint.fault_tolerance import SimulatedFailure
from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataPipeline, make_batch
from repro.models import lm
from repro.optim import adamw_init


def _tree(key):
    return {"a": jax.random.normal(key, (4, 8)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 3, t, extras={"note": "x"})
    assert latest_step(str(tmp_path)) == 3
    t2, extras = restore_checkpoint(str(tmp_path), 3, t)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extras == {"note": "x"}


def test_atomicity_incomplete_ignored(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a crashed save: tmp dir without manifest
    os.makedirs(tmp_path / "step_00000002.tmp")
    # and a completed-looking dir missing its manifest
    os.makedirs(tmp_path / "step_00000003")
    assert latest_step(str(tmp_path)) == 1


def test_integrity_check(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    path = save_checkpoint(str(tmp_path), 1, t)
    # corrupt a leaf size in the manifest
    mpath = os.path.join(path, "manifest.json")
    m = json.load(open(mpath))
    m["entries"][0]["bytes"] += 1
    json.dump(m, open(mpath, "w"))
    with pytest.raises(IOError):
        restore_checkpoint(str(tmp_path), 1, t)


def test_prune(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    for s in [1, 2, 3, 4, 5]:
        save_checkpoint(str(tmp_path), s, t)
    prune_checkpoints(str(tmp_path), keep=2)
    assert latest_step(str(tmp_path)) == 5
    assert not os.path.exists(tmp_path / "step_00000001")


def _mini_training(tmp_path, n_steps, inject_at=None, start_fresh=True):
    cfg = ARCHS["smollm-135m"].reduced()
    shape = ShapeConfig("t", 32, 2, "train")
    pipe = DataPipeline(cfg, shape, seed=0)
    mgr = RestartManager(str(tmp_path), save_every=5)

    def init_fn():
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": adamw_init(params)}

    state, extras, start = mgr.resume_or_init(init_fn)
    if extras.get("data"):
        pipe.load_state_dict(extras["data"])

    jstep = jax.jit(lambda p, o, b: lm.train_step(cfg, p, o, b, 1e-3))

    def step_fn(state, step):
        batch = pipe.next_batch()
        params, opt, metrics = jstep(state["params"], state["opt"], batch)
        return {"params": params, "opt": opt}, metrics

    return mgr.run(state, start, n_steps, step_fn,
                   data_state_fn=lambda: {"data": pipe.state_dict()},
                   inject_failure_at=inject_at, log_every=0,
                   log_fn=lambda *a: None)


def test_restart_after_simulated_failure(tmp_path):
    with pytest.raises(SimulatedFailure):
        _mini_training(tmp_path, 20, inject_at=12)
    assert latest_step(str(tmp_path)) == 10      # last periodic save
    # relaunch resumes from step 10 and completes
    state, history = _mini_training(tmp_path, 20)
    assert history[0]["step"] == 10
    assert history[-1]["step"] == 19
    assert latest_step(str(tmp_path)) == 20


def test_nan_quarantine(tmp_path):
    mgr = RestartManager(str(tmp_path), save_every=2, max_nan_retries=3)
    state0 = {"x": jnp.zeros(3)}
    save_checkpoint(str(tmp_path), 0, state0)

    def step_fn(state, step):
        loss = float("nan") if step == 3 else 1.0 / (step + 1)
        return state, {"loss": jnp.asarray(loss)}

    state, history = mgr.run(state0, 0, 6, step_fn, log_every=0,
                             log_fn=lambda *a: None)
    steps = [h["step"] for h in history]
    assert 3 not in steps                         # poisoned step skipped
    assert 5 in steps


def test_elastic_restore_resharding(tmp_path):
    """Restore the same checkpoint under a different sharding (elastic)."""
    t = {"w": jnp.arange(64.0).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 1, t)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    sh = {"w": NamedSharding(mesh, P("data", None))}
    t2, _ = restore_checkpoint(str(tmp_path), 1, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(t2["w"]), np.asarray(t["w"]))
    assert t2["w"].sharding == sh["w"]


def test_crc32_corruption_detected(tmp_path):
    """Bit rot in a leaf file (size-preserving) must fail the restore —
    the nbytes check alone cannot see it."""
    t = _tree(jax.random.PRNGKey(0))
    path = save_checkpoint(str(tmp_path), 1, t)
    m = json.load(open(os.path.join(path, "manifest.json")))
    fname = os.path.join(path, m["entries"][0]["file"])
    arr = np.load(fname)
    arr.flat[0] += 1.0                       # same nbytes, different bits
    np.save(fname, arr)
    with pytest.raises(IOError, match="crc32"):
        restore_checkpoint(str(tmp_path), 1, t)


def test_treedef_mismatch_detected(tmp_path):
    """A restore target with the same leaf count but different structure
    must be rejected, not silently restored leaf-by-leaf."""
    t = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, t)
    other = {"a": t["a"], "z": {"q": t["b"]["c"]}}    # 2 leaves, new keys
    with pytest.raises(ValueError, match="structure"):
        restore_checkpoint(str(tmp_path), 1, other)


def test_stray_entries_tolerated(tmp_path):
    """``step_old/`` backups and loose files must not trip the step
    parser in latest_step or prune_checkpoints."""
    t = _tree(jax.random.PRNGKey(0))
    for s in [1, 2, 3]:
        save_checkpoint(str(tmp_path), s, t)
    os.makedirs(tmp_path / "step_old")
    (tmp_path / "notes.txt").write_text("scratch")
    os.makedirs(tmp_path / "step_00000002.tmp")      # crashed save
    assert latest_step(str(tmp_path)) == 3
    prune_checkpoints(str(tmp_path), keep=1)
    assert latest_step(str(tmp_path)) == 3
    assert (tmp_path / "step_old").exists()          # not a checkpoint: kept
    assert (tmp_path / "notes.txt").exists()
    assert not (tmp_path / "step_00000002.tmp").exists()   # GCed


def test_gc_incomplete(tmp_path):
    from repro.checkpoint import gc_incomplete
    t = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, t)
    os.makedirs(tmp_path / "step_00000005.tmp")
    removed = gc_incomplete(str(tmp_path))
    assert removed == ["step_00000005.tmp"]
    assert latest_step(str(tmp_path)) == 1           # complete one untouched


def test_straggler_monitor_bounded_memory():
    """record() keeps O(window) history (a deque), not an unbounded list,
    and stats() summarises for RunResult.health."""
    from repro.checkpoint import StragglerMonitor
    mon = StragglerMonitor(window=16)
    for _ in range(10_000):
        mon.record(0.01)
    assert len(mon.times) == 16
    assert mon.recorded == 10_000
    assert mon.record(1.0)                           # 100x median: flagged
    s = mon.stats()
    assert s["flagged"] == 1 and s["recorded"] == 10_001
    assert s["window_max_s"] == 1.0
    assert abs(s["window_median_s"] - 0.01) < 1e-12


def test_data_pipeline_determinism_and_cursor():
    cfg = ARCHS["smollm-135m"].reduced()
    shape = ShapeConfig("t", 16, 2, "train")
    p1 = DataPipeline(cfg, shape, seed=5)
    b1 = [p1.next_batch()["tokens"] for _ in range(4)]
    # resume from cursor 2 reproduces batches 2,3 exactly
    p2 = DataPipeline(cfg, shape, seed=5)
    p2.load_state_dict({"seed": 5, "step": 2})
    b2 = [p2.next_batch()["tokens"] for _ in range(2)]
    np.testing.assert_array_equal(np.asarray(b1[2]), np.asarray(b2[0]))
    np.testing.assert_array_equal(np.asarray(b1[3]), np.asarray(b2[1]))
    # different seed -> different stream
    b3 = make_batch(cfg, shape, seed=6, step=0)
    assert not np.array_equal(np.asarray(b1[0]), np.asarray(b3["tokens"]))

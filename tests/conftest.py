import os
import sys

# Neuro-core accuracy needs x64 (repro.core enables it at import); tests that
# exercise the LM zoo use explicit f32 dtypes so both coexist.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

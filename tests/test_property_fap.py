"""Property-based tests (hypothesis) of the FAP execution model's invariants:

  1. conservative-lookahead progress: with all delays >= min_delay > 0, the
     earliest neuron's horizon strictly exceeds its clock (no deadlock),
  2. the non-speculative guarantee: every event is delivered at a receiver
     clock <= its delivery time (never in the receiver's past),
  3. event conservation: delivered + pending == emitted * fan-out.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import exec_common as xc  # noqa: E402
from repro.core import network  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 24), st.integers(1, 6))
def test_horizon_progress_no_deadlock(seed, n, k):
    net = network.make_network(n, k_in=min(k, n - 1), seed=seed)
    dnet = xc.to_device(net)
    rng = np.random.default_rng(seed)
    t_clock = jnp.asarray(rng.uniform(0.0, 5.0, n))
    horizon = xc.horizon_times(dnet, n, t_clock, t_end=1e9)
    tmin_idx = int(np.argmin(np.asarray(t_clock)))
    # the globally earliest neuron can ALWAYS advance by >= min_delay
    assert float(horizon[tmin_idx]) >= float(t_clock[tmin_idx]) + net.min_delay - 1e-12
    assert net.min_delay >= network.MIN_DELAY - 1e-12


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_nonspeculative_event_delivery(seed):
    """Events produced by a FAP round are never earlier than the receiving
    neuron's horizon at emission time (paper §2.4's no-backstepping
    invariant), by the argument in exec_fap's module docstring."""
    n, k = 12, 4
    net = network.make_network(n, k_in=k, seed=seed)
    dnet = xc.to_device(net)
    rng = np.random.default_rng(seed)
    t_clock = jnp.asarray(rng.uniform(0.0, 3.0, n))
    horizon = xc.horizon_times(dnet, n, t_clock, t_end=1e9)
    # a RUNNABLE neuron spikes somewhere inside its (clock, horizon] window.
    # (in reachable states t <= horizon — horizons are monotone in the pre
    # clocks — so only runnable neurons, horizon > clock, may spike)
    frac = rng.uniform(0.1, 1.0, n)
    gap = np.maximum(np.asarray(horizon) - np.asarray(t_clock), 0.0)
    t_spike = np.asarray(t_clock) + frac * gap
    spiked = (rng.random(n) < 0.5) & (gap > 0)
    tgt, t_ev, wa, wg, valid = xc.fanout(dnet, jnp.asarray(spiked),
                                         jnp.asarray(t_spike))
    t_ev, tgt, valid = map(np.asarray, (t_ev, tgt, valid))
    # the receiver can have advanced AT MOST to horizon[tgt] this round, and
    # horizon[tgt] <= t_clock_old[pre] + delay(e) < t_spike(pre) + delay(e)
    # = t_ev  — so no event ever lands in a receiver's past.
    receiver_horizon = np.asarray(horizon)[tgt]
    ok = t_ev[valid] >= receiver_horizon[valid] - 1e-9
    assert ok.all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 10))
def test_fanout_conservation(seed, n):
    net = network.make_network(n, k_in=2, seed=seed)
    dnet = xc.to_device(net)
    rng = np.random.default_rng(seed)
    spiked = rng.random(n) < 0.5
    t_spike = rng.uniform(0, 1, n)
    tgt, t_ev, wa, wg, valid = xc.fanout(dnet, jnp.asarray(spiked),
                                         jnp.asarray(t_spike))
    # each spiking neuron emits exactly out-degree events
    out_deg = np.bincount(np.asarray(net.pre), minlength=n)
    assert int(np.asarray(valid).sum()) == int(out_deg[spiked].sum())


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(8, 48), st.integers(1, 12))
def test_compact_selection_always_keeps_earliest_runnable(seed, n, cap):
    """Progress guarantee of the active-set path (ISSUE 4): whatever the
    clocks and the cap, the compacted batch contains a globally-earliest
    runnable neuron — so with delays >= min_delay the conservative-
    lookahead argument survives batch capping (overflow lanes only roll,
    the frontier head never stalls)."""
    from repro.core import exec_common as xcm

    rng = np.random.default_rng(seed)
    t_clock = jnp.asarray(np.round(rng.uniform(0.0, 5.0, n), 3))  # incl ties
    runnable = jnp.asarray(rng.random(n) < 0.6)
    if not bool(runnable.any()):
        runnable = runnable.at[int(rng.integers(n))].set(True)
    ids, cnt = xcm.compact_frontier(runnable, t_clock, cap)
    ids = np.asarray(ids)
    kept = ids[ids < n]
    run_np = np.asarray(runnable)
    clocks = np.asarray(t_clock)
    # every kept lane is runnable, kept lanes are unique and index-sorted
    assert run_np[kept].all()
    assert len(np.unique(kept)) == len(kept)
    # at least one lane is always kept, and the batch contains a globally
    # earliest runnable neuron (min over kept == min over runnable) even
    # under clock ties at the selection threshold (the force-include)
    assert len(kept) >= 1
    assert clocks[kept].min() == clocks[run_np].min()
    # no overflow -> the batch is exactly the frontier
    if int(run_np.sum()) <= cap:
        assert set(kept.tolist()) == set(np.flatnonzero(run_np).tolist())


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_delay_distribution_matches_paper(seed):
    """Fig. 3 shape: all delays in [0.1, 7] ms, mode well above the min,
    only a tiny fraction at the BSP communication interval."""
    net = network.make_network(256, k_in=16, seed=seed)
    d = net.delay
    assert d.min() >= network.MIN_DELAY - 1e-12
    assert d.max() <= network.MAX_DELAY + 1e-12
    frac_at_min = (d <= network.MIN_DELAY + 0.05).mean()
    assert frac_at_min < 0.15
    assert np.median(d) > 3 * network.MIN_DELAY

"""Optimized SPMD FAP round: transport-layer coverage on a 4-device
host-platform mesh (subprocess — jax device count locks at first init).

Acceptance (ISSUE 2): sparse and allgather transports produce event-for-event
identical spike trains when no parcel overflows; the sparse transport's
spike-parcel collective bytes are a function of the static parcel cap, NOT of
N (asserted at two values of N from the compiled HLO's per-channel
attribution); parcel-cap overflow fires the drop counter, never silent.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import morphology, network
from repro.core.cell import CellModel
from repro.distributed.exchange import ExchangeSpec
from repro.distributed.fap_spmd import (PaperNeuroSpec, build_fap_round,
                                        run_fap_spmd)
from repro.launch.hlo_analysis import collective_channel_bytes
from repro.launch.mesh import make_mesh_compat

mesh = make_mesh_compat((2, 2), ("data", "model"))
model = CellModel(morphology.soma_only())
n = 32
net = network.make_network(n, k_in=4, seed=3)
rng = np.random.default_rng(1)
iinj = 0.16 + 0.004 * rng.standard_normal(n)
out = {}


def trains(res):
    ts, c = np.asarray(res.rec.times), np.asarray(res.rec.count)
    return [sorted(float(t) for t in ts[i][: c[i]]) for i in range(len(c))]


runs = {
    "global": dict(optimized=False),
    "allgather": dict(optimized=True, transport="allgather"),
    "sparse": dict(optimized=True, transport="sparse",
                   exchange=ExchangeSpec(parcel_cap=8)),
    "sparse_wheel": dict(optimized=True, transport="sparse", queue="wheel",
                         exchange=ExchangeSpec(parcel_cap=8,
                                               compact_impl="jnp")),
    # active-set compaction (ISSUE 4): shard-local compact -> step ->
    # scatter composed with the sparse transport; full shard width
    # (batch_cap=0) must be event-for-event identical to the dense batch
    "sparse_compact": dict(optimized=True, transport="sparse",
                           exchange=ExchangeSpec(parcel_cap=8),
                           batch="compact"),
    # activity-proportional delivery (ISSUE 5): two-phase ragged parcels
    "sparse_ragged": dict(optimized=True, transport="sparse_ragged",
                          exchange=ExchangeSpec(parcel_cap=8)),
    # ... and the full stack: compact batch + compact fan-out +
    # incremental SPMD horizon + ragged parcels, all at once
    "full_stack": dict(optimized=True, transport="sparse_ragged",
                       exchange=ExchangeSpec(parcel_cap=8),
                       batch="compact", fanout="compact", spike_cap=8,
                       horizon="incremental"),
}
for name, kw in runs.items():
    res, rounds = run_fap_spmd(model, net, iinj, 6.0, mesh, max_rounds=60,
                               **kw)
    out[name] = {"trains": trains(res), "dropped": int(res.dropped),
                 "failed": bool(res.failed), "rounds": rounds,
                 "comm": res.comm}

# independent anchor: the single-host FAP runner (exec_fap) with matching
# knobs — catches driver-level bugs that would cancel out of the pairwise
# SPMD comparisons
from repro.core import exec_fap
res_ref = exec_fap.run_fap_vardt(model, net, iinj, 6.0, step_budget=8,
                                 ev_cap=32)
out["single_host"] = {"trains": trains(res_ref),
                      "dropped": int(res_ref.dropped)}

# forced parcel overflow: hot network + cap=1
iinj_hot = 0.20 + 0.004 * rng.standard_normal(n)
res_of, _ = run_fap_spmd(model, net, iinj_hot, 6.0, mesh, transport="sparse",
                         exchange=ExchangeSpec(parcel_cap=1), max_rounds=60)
out["overflow_dropped"] = int(res_of.dropped)

# ragged overflow: the largest class == the static cap, so a hot network
# over cap must fire the same drop counter through the classed exchange
res_rof, _ = run_fap_spmd(model, net, iinj_hot, 6.0, mesh,
                          transport="sparse_ragged",
                          exchange=ExchangeSpec(parcel_cap=2), max_rounds=60)
out["ragged_overflow_dropped"] = int(res_rof.dropped)

# locality-aware placement (ISSUE 3): a block-structured net run through the
# sparse transport with the greedy placement permutation — spike trains must
# come back in the caller's neuron order, identical to the single-host
# exec_fap anchor on the unpermuted net
from repro.core.topology import TopologyConfig
from repro.distributed import placement as plc

net_blk = network.make_network(n, k_in=4, seed=3,
                               topology=TopologyConfig("block", n_blocks=4,
                                                       p_in=0.95))
res_blk_ref = exec_fap.run_fap_vardt(model, net_blk, iinj, 6.0,
                                     step_budget=8, ev_cap=32)
res_blk, _ = run_fap_spmd(model, net_blk, iinj, 6.0, mesh, transport="sparse",
                          exchange=ExchangeSpec(parcel_cap=8),
                          placement="greedy", max_rounds=60)
out["placed_anchor"] = {"trains": trains(res_blk_ref),
                        "dropped": int(res_blk_ref.dropped)}
out["placed"] = {"trains": trains(res_blk), "dropped": int(res_blk.dropped),
                 "failed": bool(res_blk.failed)}

# per-channel bytes: block+placement vs uniform at the same N — the notify
# frontier (and its gather) must shrink by ~the measured frontier ratio
nn = 256
net_u = network.make_network(nn, k_in=4, seed=5)
net_b = network.make_network(nn, k_in=4, seed=5,
                             topology=TopologyConfig("block", n_blocks=4,
                                                     p_in=0.98))
pl = plc.compute_placement(net_b, 4, method="greedy")
spec = PaperNeuroSpec(n_neurons=nn, k_in=4, ev_cap=8, t_end=6.0)
for tag, netx in (("uniform", net_u), ("block_placed",
                                       plc.place_network(net_b, pl))):
    fn, args, sh = build_fap_round(model, spec, mesh, optimized=True,
                                   transport="sparse",
                                   exchange=ExchangeSpec(parcel_cap=8),
                                   net=netx)
    txt = jax.jit(fn, in_shardings=sh).lower(*args).compile().as_text()
    out[f"bytes/topo/{tag}"] = collective_channel_bytes(txt)
out["frontier_ratio"] = (plc.frontier_stats(net_u, 4)["F"]
                         / max(1, plc.frontier_stats(net_b, 4, pl)["F"]))

# per-channel collective bytes of the compiled round at two values of N
cap = 8
for nn in (64, 256):
    netn = network.make_network(nn, k_in=4, seed=5)
    spec = PaperNeuroSpec(n_neurons=nn, k_in=4, ev_cap=8, t_end=6.0)
    for tr in ("sparse", "allgather"):
        fn, args, sh = build_fap_round(
            model, spec, mesh, optimized=True, transport=tr,
            exchange=ExchangeSpec(parcel_cap=cap), net=netn)
        txt = jax.jit(fn, in_shardings=sh).lower(*args).compile().as_text()
        out[f"bytes/{tr}/n{nn}"] = collective_channel_bytes(txt)

# ragged per-class attribution: each class branch's sized all_to_all is
# separately scoped (exchange_parcel_c<cap>), so its bytes are measured
# from the lowered module; the class ladder's payloads must sit strictly
# below the static cap's except the last, which equals it
from repro.distributed.exchange import class_tag

xspec = ExchangeSpec(parcel_cap=cap)
fn, args, sh = build_fap_round(model, spec, mesh, optimized=True,
                               transport="sparse_ragged", exchange=xspec,
                               net=netn)
txt = jax.jit(fn, in_shardings=sh).lower(*args).compile().as_text()
ladder = xspec.class_ladder()
tags = tuple(class_tag(c) for c in ladder)
out["ragged_classes"] = list(ladder)
out["bytes/ragged_by_class"] = collective_channel_bytes(txt, tags=tags)
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def spmd_out():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=ROOT)
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


pytestmark = pytest.mark.slow


def _assert_same_trains(a, b):
    assert len(a) == len(b)
    for ta, tb in zip(a, b):
        assert len(ta) == len(tb)
        if ta:
            assert max(abs(x - y) for x, y in zip(ta, tb)) < 1e-9


def test_optimized_matches_global_path(spmd_out):
    """optimized=True (shard_map + explicit channels) reproduces the
    GSPMD-lowered global round event for event."""
    assert spmd_out["global"]["dropped"] == 0
    assert sum(len(t) for t in spmd_out["global"]["trains"]) > 0
    _assert_same_trains(spmd_out["global"]["trains"],
                        spmd_out["allgather"]["trains"])


def test_spmd_driver_matches_single_host_runner(spmd_out):
    """run_fap_spmd anchored against the independent exec_fap runner (same
    knobs) — the SPMD paths must reproduce the single-host spike trains,
    not merely agree with each other."""
    assert spmd_out["single_host"]["dropped"] == 0
    _assert_same_trains(spmd_out["single_host"]["trains"],
                        spmd_out["global"]["trains"])


def test_sparse_matches_allgather(spmd_out):
    """Acceptance: with no parcel overflow the sparse transport delivers the
    identical event stream — including through the wheel queue."""
    for name in ("sparse", "sparse_wheel"):
        assert spmd_out[name]["dropped"] == 0, name
        assert not spmd_out[name]["failed"]
        _assert_same_trains(spmd_out["allgather"]["trains"],
                            spmd_out[name]["trains"])


def test_compact_batch_matches_dense(spmd_out):
    """Acceptance (ISSUE 4): the shard-local active-set compaction
    (batch="compact") composed with the sparse transport reproduces the
    dense batch's event stream exactly, with nothing dropped."""
    assert spmd_out["sparse_compact"]["dropped"] == 0
    assert not spmd_out["sparse_compact"]["failed"]
    _assert_same_trains(spmd_out["allgather"]["trains"],
                        spmd_out["sparse_compact"]["trains"])
    assert spmd_out["sparse_compact"]["rounds"] == \
        spmd_out["sparse"]["rounds"]


def test_ragged_matches_allgather(spmd_out):
    """Acceptance (ISSUE 5): the two-phase ragged transport delivers the
    identical event stream — class sizing is pure capacity, never
    semantics."""
    assert spmd_out["sparse_ragged"]["dropped"] == 0
    assert not spmd_out["sparse_ragged"]["failed"]
    _assert_same_trains(spmd_out["allgather"]["trains"],
                        spmd_out["sparse_ragged"]["trains"])


def test_full_stack_matches_allgather(spmd_out):
    """Acceptance (ISSUE 5): compact batch + compact fan-out + incremental
    SPMD horizon + ragged parcels, all composed, reproduce the dense
    reference event-for-event in the same number of rounds."""
    assert spmd_out["full_stack"]["dropped"] == 0
    assert not spmd_out["full_stack"]["failed"]
    _assert_same_trains(spmd_out["allgather"]["trains"],
                        spmd_out["full_stack"]["trains"])
    assert spmd_out["full_stack"]["rounds"] == spmd_out["sparse"]["rounds"]


def test_ragged_bytes_below_static_cap(spmd_out):
    """Acceptance (ISSUE 5): realized ragged parcel bytes on the (quiet)
    driven run sit strictly below the static-cap transport's and never
    exceed them; the per-class HLO attribution confirms every class but
    the last is strictly smaller than the static exchange and the last is
    exactly it."""
    sp = spmd_out["sparse"]["comm"]["parcel_bytes"]
    rg = spmd_out["sparse_ragged"]["comm"]["parcel_bytes"]
    assert spmd_out["sparse_ragged"]["rounds"] == spmd_out["sparse"]["rounds"]
    assert 0 < rg < sp
    # per-class lowered bytes: ascending, last == static sparse
    ladder = spmd_out["ragged_classes"]
    by_class = spmd_out["bytes/ragged_by_class"]
    static = spmd_out["bytes/sparse/n256"]["exchange_parcel"]
    per_class = [by_class[f"exchange_parcel_c{c}/"] for c in ladder]
    assert per_class == sorted(per_class)
    assert all(b < static for b in per_class[:-1])
    assert per_class[-1] == static
    # telemetry cross-check: realized bytes bounded by whole rounds of the
    # HLO-measured class payloads (parcel bytes are cap-sized and N-free,
    # so the n=256 lowering prices the driven n=32 run's classes too)
    rounds = spmd_out["sparse_ragged"]["rounds"]
    lo, hi = per_class[0], per_class[-1]
    assert rounds * lo <= rg <= rounds * hi


def test_parcel_overflow_detected_never_silent(spmd_out):
    """cap=1 on a hot network must fire the drop counter — through the
    static-cap transport and through the ragged classed exchange alike."""
    assert spmd_out["overflow_dropped"] > 0
    assert spmd_out["ragged_overflow_dropped"] > 0


def test_parcel_bytes_scale_with_cap_not_n(spmd_out):
    """Acceptance: the sparse spike-parcel channel's collective bytes are a
    function of (n_shards, parcel_cap) only — identical at N=64 and N=256 —
    while the dense reference transport's grow with N."""
    sp64 = spmd_out["bytes/sparse/n64"]["exchange_parcel"]
    sp256 = spmd_out["bytes/sparse/n256"]["exchange_parcel"]
    ag64 = spmd_out["bytes/allgather/n64"]["exchange_parcel"]
    ag256 = spmd_out["bytes/allgather/n256"]["exchange_parcel"]
    assert sp64 > 0 and sp64 == sp256
    assert ag256 >= 3 * ag64                    # ~linear in N (4x neurons)
    # and the cap-sized parcels beat the dense channel already at N=256
    assert sp256 < ag256


def test_notify_channel_attributed(spmd_out):
    """Both transports tag their clock-notification collectives."""
    for tr in ("sparse", "allgather"):
        assert spmd_out[f"bytes/{tr}/n256"]["exchange_notify"] > 0


def test_placement_roundtrip_matches_single_host_anchor(spmd_out):
    """Acceptance (ISSUE 3): the SPMD round on a greedy-placed block net
    returns spike trains event-for-event identical to the single-host
    exec_fap anchor on the unpermuted net — the placement permutation is
    applied before sharding and inverted on outputs."""
    assert spmd_out["placed"]["dropped"] == 0
    assert not spmd_out["placed"]["failed"]
    assert sum(len(t) for t in spmd_out["placed_anchor"]["trains"]) > 0
    _assert_same_trains(spmd_out["placed_anchor"]["trains"],
                        spmd_out["placed"]["trains"])


def test_placement_cuts_notify_bytes_by_locality_factor(spmd_out):
    """Acceptance (ISSUE 3): on the 4-shard mesh the block-structured net
    under greedy placement cuts the notify-channel collective bytes vs
    uniform-random by at least ~the measured frontier (locality) ratio;
    parcel bytes stay cap-sized for both."""
    uni = spmd_out["bytes/topo/uniform"]
    blk = spmd_out["bytes/topo/block_placed"]
    f_ratio = spmd_out["frontier_ratio"]
    assert f_ratio >= 2.0
    ratio = uni["exchange_notify"] / max(1, blk["exchange_notify"])
    assert ratio >= max(2.0, 0.8 * f_ratio), (ratio, f_ratio)
    assert blk["exchange_parcel"] == uni["exchange_parcel"]

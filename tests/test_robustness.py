"""Preemption tolerance, single-host: round-boundary SimCarry
checkpoint/restore, kill/resume identity, the health watchdog's
quarantine-and-rollback, and the detected-never-silent escalation ladder.

The identity contract: every robust run shares ONE compiled round per
runner (``run(**knobs)`` selects the host-stepped driver at call time),
so a killed-and-resumed or poisoned-and-rolled-back run is bit-identical
to the uninterrupted host-stepped run.  The jitted ``while_loop`` fast
path agrees to floating-point ulp only (XLA fuses the standalone round
differently) — asserted as such, not as bitwise.
"""
import numpy as np
import pytest

from repro.checkpoint import FaultPlan, SimulatedFailure
from repro.core import exec_bsp, exec_fap, morphology, network
from repro.core import exec_common as xc
from repro.core.cell import CellModel

N = 12
T_END = 12.0

# (label, runner kwargs) — dense/wheel queues x dense/compact batch; the
# compact config also exercises the incremental horizon hcarry leg of the
# SimCarry snapshot
CONFIGS = {
    "dense": dict(queue="dense"),
    "wheel": dict(queue="wheel"),
    "compact": dict(queue="dense", batch="compact", batch_cap=8),
}


@pytest.fixture(scope="module")
def setup():
    model = CellModel(morphology.soma_only())
    net = network.make_network(N, k_in=4, seed=3)
    rng = np.random.default_rng(1)
    iinj = 0.16 + 0.004 * rng.standard_normal(N)
    return model, net, iinj


@pytest.fixture(scope="module")
def runners(setup):
    """One runner (one compiled round) per config — every scenario below
    reuses these, so the whole module pays 3 compiles."""
    model, net, iinj = setup
    return {k: exec_fap.make_fap_vardt_runner(model, net, iinj, T_END, **kw)
            for k, kw in CONFIGS.items()}


@pytest.fixture(scope="module")
def baselines(runners):
    """Uninterrupted host-stepped run per config (the identity target)."""
    out = {}
    for k, run in runners.items():
        res, rounds = run(watchdog=True)
        assert not bool(res.failed) and int(res.dropped) == 0
        out[k] = (np.asarray(res.rec.times), np.asarray(res.rec.count),
                  int(rounds))
    return out


def _train(res):
    return np.asarray(res.rec.times), np.asarray(res.rec.count)


def test_fast_path_unchanged_and_close(runners, baselines):
    """run() with no knobs still takes the jitted while_loop (no health
    field) and agrees with the host-stepped driver to fp ulp."""
    run = runners["dense"]
    res, _ = run()
    assert res.health is None
    t0, c0, _ = baselines["dense"]
    t1, c1 = _train(res)
    assert np.array_equal(c0, c1)
    for i in range(N):
        np.testing.assert_allclose(np.sort(t0[i][:c0[i]]),
                                   np.sort(t1[i][:c1[i]]), atol=1e-6)


def test_health_populated(runners, baselines):
    res, rounds = runners["dense"](watchdog=True)
    h = res.health
    assert h["watchdog"] and h["checks"] == int(rounds)
    assert h["nonfinite_rounds"] == 0 and h["clock_regressions"] == 0
    assert h["horizon_violations"] == 0 and h["rollbacks"] == 0
    assert h["dropped_events"] == 0 and not h["rollback_exhausted"]
    assert h["straggler"]["recorded"] == int(rounds)


@pytest.mark.parametrize("cfg", list(CONFIGS))
def test_kill_resume_identity(runners, baselines, cfg, tmp_path):
    """SimulatedFailure mid-run; relaunch with resume=True -> the spike
    train is bit-identical to the uninterrupted run, across queue impls
    and the compact/incremental-horizon path."""
    run = runners[cfg]
    t0, c0, rounds0 = baselines[cfg]
    kill = max(2, rounds0 // 2)
    with pytest.raises(SimulatedFailure):
        run(checkpoint_every=4, ckpt_dir=str(tmp_path),
            fault=FaultPlan(fail_at_round=kill))
    res, rounds = run(checkpoint_every=4, ckpt_dir=str(tmp_path),
                      resume=True)
    t1, c1 = _train(res)
    assert np.array_equal(c0, c1)
    assert np.array_equal(t0, t1)
    assert int(rounds) == rounds0
    assert res.health["resumed_from"] == (kill // 4) * 4


def test_poison_watchdog_rollback_identity(runners, baselines, tmp_path):
    """An injected non-finite lane is detected the round it appears,
    rolled back to the last checkpoint, and the completed run is
    bit-identical — never silently propagated."""
    run = runners["dense"]
    t0, c0, _ = baselines["dense"]
    res, _ = run(checkpoint_every=4, ckpt_dir=str(tmp_path),
                 fault=FaultPlan(poison_at_round=9, poison_lane=3))
    assert res.health["nonfinite_rounds"] >= 1
    assert res.health["rollbacks"] >= 1
    assert not bool(res.failed)
    t1, c1 = _train(res)
    assert np.array_equal(c0, c1) and np.array_equal(t0, t1)


def test_rollback_exhaustion_escalates(runners, tmp_path):
    """A persistent fault (every-round poison) exhausts the bounded
    retries and escalates to RunResult.failed + health, never loops."""
    run = runners["dense"]
    res, _ = run(checkpoint_every=4, ckpt_dir=str(tmp_path),
                 max_rollbacks=1,
                 fault=FaultPlan(mutate=lambda r, c: xc.poison_lane(c, 0)))
    assert bool(res.failed)
    assert res.health["rollback_exhausted"]
    assert res.health["rollbacks"] == 1


def test_simcarry_roundtrip_every_field(runners, tmp_path):
    """Every SimCarry leaf — BDFState incl. the PR 6 Jacobian-cache
    fields, queue arrays, the horizon carry, spike cursor and counters —
    survives save/restore bitwise (mirrors the BDFState round-trip
    tests)."""
    run = runners["compact"]
    sc = run.pack(run.init_carry())
    # sanity: the snapshot really carries the PR 6 solver-state fields
    for f in ("gamma_saved", "nstlp", "factors", "zn", "t", "h"):
        assert hasattr(sc.sts, f)
    assert len(sc.hcarry) == 1          # incremental horizon leg present
    xc.save_sim_checkpoint(str(tmp_path), 7, sc, extras={"k": 1})
    sc2, extras, skipped = xc.restore_sim_checkpoint(str(tmp_path), 7, sc)
    assert extras == {"k": 1} and skipped == []
    import jax
    flat = jax.tree_util.tree_flatten_with_path(sc)[0]
    flat2 = jax.tree_util.tree_flatten_with_path(sc2)[0]
    assert len(flat) == len(flat2)
    for (kp, a), (_, b) in zip(flat, flat2):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"leaf {jax.tree_util.keystr(kp)} not bitwise equal")


def test_simcarry_elastic_skip_reports_hcarry_only(runners, tmp_path):
    """A restore target whose hcarry width changed (elastic resume) keeps
    the like-value and reports the path; every other leaf restores."""
    run = runners["compact"]
    sc = run.pack(run.init_carry())
    xc.save_sim_checkpoint(str(tmp_path), 1, sc)
    import jax.numpy as jnp
    like = sc._replace(hcarry=(jnp.zeros(sc.hcarry[0].shape[0] // 2),))
    sc2, _, skipped = xc.restore_sim_checkpoint(str(tmp_path), 1, like)
    assert len(skipped) == 1 and "hcarry" in skipped[0]
    np.testing.assert_array_equal(np.asarray(sc2.sts.zn),
                                  np.asarray(sc.sts.zn))


def test_fallback_ladder_composes_with_checkpointing(setup, tmp_path):
    """spike_cap=1 forces the compact fan-out onto its dense fallback
    (identical events, never a drop) — kill/resume under that ladder must
    still be bit-identical and drop-free."""
    model, net, iinj = setup
    run = exec_fap.make_fap_vardt_runner(model, net, iinj, T_END,
                                         fanout="compact", spike_cap=1)
    res0, rounds0 = run(watchdog=True)
    assert int(res0.dropped) == 0 and not bool(res0.failed)
    with pytest.raises(SimulatedFailure):
        run(checkpoint_every=4, ckpt_dir=str(tmp_path),
            fault=FaultPlan(fail_at_round=max(2, int(rounds0) // 2)))
    res1, _ = run(checkpoint_every=4, ckpt_dir=str(tmp_path), resume=True)
    assert int(res1.dropped) == 0
    assert res1.health["dropped_events"] == 0
    assert np.array_equal(np.asarray(res0.rec.times),
                          np.asarray(res1.rec.times))


def test_bsp_kill_resume_identity(setup, tmp_path):
    """The BSP vardt runner shares the same driver: kill at a window
    boundary, resume, bit-identical spike train."""
    model, net, iinj = setup
    run = exec_bsp.make_bsp_vardt_runner(model, net, iinj, 6.0)
    res0 = run(watchdog=True)
    assert not bool(res0.failed)
    with pytest.raises(SimulatedFailure):
        run(checkpoint_every=10, ckpt_dir=str(tmp_path),
            fault=FaultPlan(fail_at_round=25))
    res1 = run(checkpoint_every=10, ckpt_dir=str(tmp_path), resume=True)
    assert res1.health["resumed_from"] == 20
    assert np.array_equal(np.asarray(res0.rec.times),
                          np.asarray(res1.rec.times))
    assert np.array_equal(np.asarray(res0.rec.count),
                          np.asarray(res1.rec.count))


def test_kill_resume_property(runners, baselines):
    """Hypothesis: kill at a RANDOM round under a random config; resume
    is bit-identical to the uninterrupted run.  All examples reuse the
    module's compiled runners (call-time knobs — no recompiles)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    import shutil
    import tempfile

    @hyp.settings(max_examples=8, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(cfg=st.sampled_from(sorted(CONFIGS)),
               frac=st.floats(0.1, 0.9), every=st.integers(2, 7))
    def prop(cfg, frac, every):
        run = runners[cfg]
        t0, c0, rounds0 = baselines[cfg]
        kill = max(1, int(rounds0 * frac))
        d = tempfile.mkdtemp()
        try:
            with pytest.raises(SimulatedFailure):
                run(checkpoint_every=every, ckpt_dir=d,
                    fault=FaultPlan(fail_at_round=kill))
            res, rounds = run(checkpoint_every=every, ckpt_dir=d,
                              resume=True)
            assert np.array_equal(c0, np.asarray(res.rec.count))
            assert np.array_equal(t0, np.asarray(res.rec.times))
            assert int(rounds) == rounds0
        finally:
            shutil.rmtree(d, ignore_errors=True)

    prop()

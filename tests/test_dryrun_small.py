"""Dry-run machinery at test scale: the same build/lower/compile path as the
production 512-chip run, on a small forced-host-device mesh in a subprocess
(jax device count locks at first init, so this must not share the test
process)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from repro.configs import ARCHS, get_config
from repro.configs.base import ShapeConfig
from repro.launch import dryrun
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_mesh_compat

mesh = make_mesh_compat((4, 2), ("data", "model"))
out = {}
for arch in ["smollm-135m", "qwen3-moe-30b-a3b", "rwkv6-7b", "zamba2-7b",
             "whisper-base", "internvl2-1b"]:
    cfg = get_config(arch).reduced()
    for shape in [ShapeConfig("t", 64, 8, "train"),
                  ShapeConfig("d", 64, 8, "decode")]:
        fn, args, sh = dryrun.build(cfg, shape, mesh)
        lowered = jax.jit(fn, in_shardings=sh).lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        coll, _ = collective_bytes(compiled.as_text())
        out[f"{arch}/{shape.kind}"] = {
            "temp": mem.temp_size_in_bytes,
            "coll": int(coll),
            "flops": dryrun.cost_dict(compiled).get("flops", 0.0),
        }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_small_mesh_compiles():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560,
                         cwd=ROOT)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert len(out) == 12
    for cell, rec in out.items():
        assert rec["flops"] > 0, cell
        # training cells must communicate (grad all-reduce at minimum)
        if cell.endswith("/train"):
            assert rec["coll"] > 0, cell


def test_production_mesh_shapes():
    """make_production_mesh geometry (without building it here)."""
    import math
    assert math.prod((16, 16)) == 256
    assert math.prod((2, 16, 16)) == 512


def test_dryrun_artifacts_complete():
    """The committed dry-run artifacts cover every (arch x shape x mesh)
    cell: ok for applicable cells, explicit skip records for long_500k on
    full-attention archs, plus the paper-neuro cells."""
    d = os.path.join(ROOT, "experiments", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run artifacts not generated yet")
    from repro.configs import ARCHS, SHAPES, shape_applicable
    missing, bad = [], []
    for mesh in ("single", "multi"):
        for arch, cfg in ARCHS.items():
            for shape in SHAPES.values():
                p = os.path.join(d, f"{arch}__{shape.name}__{mesh}.json")
                if not os.path.exists(p):
                    missing.append(p)
                    continue
                rec = json.load(open(p))
                applicable, _ = shape_applicable(cfg, shape)
                want = "ok" if applicable else "skipped"
                if rec.get("status") != want:
                    bad.append((p, rec.get("status"), rec.get("error", "")[:80]))
        p = os.path.join(d, f"paper-neuro__sim_round__{mesh}.json")
        if not os.path.exists(p) or json.load(open(p)).get("status") != "ok":
            bad.append((p, "missing/err", ""))
    assert not missing, missing[:5]
    assert not bad, bad[:5]

#!/usr/bin/env bash
# One-shot local gate: the tier-1 test command (ROADMAP.md) plus quick
# smokes of the event-wheel microbenchmark (sort-free insert + equivalence
# checks run inside it) and the 4-device host-platform spike-parcel
# transport benchmark (sparse-vs-allgather byte-scaling asserted inside —
# SPMD transport regressions fail here, not just on a real mesh).
# Usage: scripts/check.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# benchmark spike counts must be reproducible across processes: the regime
# hash is deterministic (crc32) since ISSUE 5, and pinning the interpreter
# hash seed removes any remaining hash-order effects in subprocess workers
export PYTHONHASHSEED=0

echo "== tier-1 tests =="
python -m pytest -x -q "$@"

echo "== event-wheel bench smoke (REPRO_BENCH_QUICK=1) =="
REPRO_BENCH_QUICK=1 python -c "from benchmarks import event_wheel; event_wheel.run()"

echo "== sparse-exchange bench smoke (4-device host platform) =="
# includes the ragged-transport axis: per-class parcel bytes from the
# lowered HLO + a driven quiet run realizing fewer bytes than the static
# cap (never more)
REPRO_BENCH_QUICK=1 python -c "from benchmarks import exchange; exchange.run()"

echo "== locality placement smoke (block topology, 4-device host mesh) =="
# asserts placed-block notify bytes < uniform-random baseline by the
# measured frontier ratio — locality regressions fail here, not only on a
# real mesh
REPRO_BENCH_QUICK=1 python -c "from benchmarks import placement; placement.run()"

echo "== active-set compaction smoke (compact == dense + flat round time) =="
# asserts the compact batch path is event-for-event identical to dense
# (incl. the burst regime with compact fan-out) and that per-round wall
# time stays ~flat in N at fixed caps on both the stepping and the
# delivery axis while the dense paths grow linearly
REPRO_BENCH_QUICK=1 python -c "from benchmarks import active_set; active_set.run()"

echo "== solver stepping smoke (reuse-don't-rebuild Newton + NDF) =="
# asserts the Jacobian-freshness policy performs < 0.5 setups per Newton
# iteration (legacy exactly 1.0), NDF takes >= 10% fewer accepted steps
# inside the spike-time accuracy envelope, and the per-Newton-round
# linear algebra beats the per-iteration rebuild by >= 1.3x on CPU
REPRO_BENCH_QUICK=1 python -c "from benchmarks import solver; solver.run()"

echo "== kill-resume smoke (checkpoint -> SimulatedFailure -> resume) =="
# asserts the resumed FAP run's spike train is bit-identical to the
# uninterrupted run and the poisoned-lane watchdog rolls back to an
# identical completion (detected, never silent); checkpoint dirs live in
# tmpdirs the suite removes itself, so the gate stays hermetic
REPRO_BENCH_QUICK=1 python -c "from benchmarks import robustness; robustness.run()"

echo "== tenant-serving smoke (admission / quarantine / shedding) =="
# asserts the multi-tenant service's isolation acceptance: a poisoned
# tenant is quarantined, retried and completes bit-identically to its
# solo run, neighbours are bit-identical to the fault-free batch, and an
# overloaded queue sheds only lowest-QoS with explicit rejection counts
REPRO_BENCH_QUICK=1 python -c "from benchmarks import serve; serve.run()"

echo "check.sh: all green"

"""Generate the §Dry-run / §Roofline markdown tables from artifacts."""
import glob
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")
from benchmarks.roofline import analyse  # noqa: E402

DD = "experiments/dryrun"


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(mesh):
    rows = []
    for p in sorted(glob.glob(f"{DD}/*__{mesh}.json")):
        r = json.load(open(p))
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped | — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — | — |")
            continue
        m = r["memory"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {fmt_bytes(m['argument_bytes'])} | "
            f"{fmt_bytes(m['temp_bytes'])} | "
            f"{r.get('hlo_flops_per_device', 0)/1e12:.2f} | "
            f"{r.get('collective_bytes_per_device', 0)/2**30:.2f} |")
    head = ("| arch | shape | status | args GiB/dev | temp GiB/dev | "
            "HLO TFLOP/dev | coll GiB/dev |\n|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def roofline_table(mesh):
    rows = []
    for p in sorted(glob.glob(f"{DD}/*__{mesh}.json")):
        r = json.load(open(p))
        a = analyse(r)
        if a is None:
            continue
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_s']:.3e} | "
            f"{a['memory_s']:.3e} | {a['collective_s']:.3e} | "
            f"**{a['dominant']}** | {a['roofline_fraction']:.3f} | "
            f"{a['model_flops_ratio']:.3f} |")
    head = ("| arch | shape | compute s | memory s | collective s | dominant "
            "| roofline frac | MODEL/HLO |\n|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("dryrun", "all"):
        print("### Single-pod (16x16 = 256 chips)\n")
        print(dryrun_table("single"))
        print("\n### Multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table("multi"))
    if which in ("roofline", "all"):
        print("\n### Roofline (single-pod)\n")
        print(roofline_table("single"))

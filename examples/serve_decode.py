"""Batched serving example: continuous-batching-lite decode over the unified
LM API (any --arch from the registry works; reduced configs on CPU).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main([
        "--arch", "qwen3-moe-30b-a3b", "--reduced",
        "--requests", "8", "--slots", "4", "--max-new", "16",
        "--cache-len", "128",
    ]))

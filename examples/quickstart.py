"""Quickstart: the paper in one file.

Simulates a single detailed (HH) neuron two ways and prints what the paper's
Fig. 5/6 show: the variable-order variable-timestep BDF integrator needs
orders of magnitude fewer steps than fixed-step Backward Euler at matched
accuracy — and synaptic discontinuities (IVP resets) are what it pays for.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import bdf, morphology
from repro.core.cell import CellModel
from repro.core.fixed_step import run_fixed


def main():
    model = CellModel(morphology.branched_tree(depth=2, seg_per_branch=2),
                      with_plasticity=True)
    print(f"neuron: {model.C} compartments, {model.n_state} ODE states "
          "(HH Na/K/leak + synapses + correlated plasticity pair)")
    t_end, iinj = 200.0, 0.05          # gentle subthreshold drive (quiet regime)

    # --- reference fixed-step (paper method 2a) --------------------------
    y_fix, n_fix, _ = run_fixed(model, model.init_state(), t_end, iinj,
                                method="derivimplicit", dt=0.025)
    print(f"fixed dt=25us     : {n_fix:6d} steps")

    # --- the paper's solver: CVODE-style BDF(1..5) -----------------------
    opts = bdf.BDFOptions(atol=1e-3)
    st = bdf.reinit(model, 0.0, model.init_state(), iinj, opts)
    st = jax.jit(lambda s: bdf.advance_to(model, s, t_end, iinj, opts))(st)
    print(f"vardt BDF atol=1e-3: {int(st.nst):6d} steps "
          f"({n_fix / int(st.nst):.0f}x fewer), final order q={int(st.q)}, "
          f"h={float(st.h):.3f} ms, newton iters={int(st.nni)}")
    dv = abs(float(st.zn[0][0]) - float(y_fix[0]))
    print(f"|V_soma difference| = {dv:.4f} mV")

    # --- a synaptic discontinuity = IVP reset ----------------------------
    st = bdf.deliver_event(model, st, 5e-3, 0.0, iinj, opts)
    print(f"after synaptic event: order reset to q={int(st.q)}, "
          f"h={float(st.h):.5f} ms  <- the cost the paper's event-grouping "
          "variants amortise")
    st = jax.jit(lambda s: bdf.advance_to(model, s, t_end + 50.0, iinj,
                                          opts))(st)
    print(f"recovered: q={int(st.q)}, h={float(st.h):.3f} ms, "
          f"failed={bool(st.failed)}")


if __name__ == "__main__":
    main()

"""End-to-end driver (the paper's kind of workload is *simulation*):
a scaled digital-reconstruction run in the spirit of paper §3.4/Fig. 8 —
a mixed-regime population (Fig. 10 percentages) simulated with

  (2a) the reference BSP fixed-step implicit solver, and
  (2c) this paper's fully-asynchronous variable-timestep method
       (+ the event-grouping variants),

printing the event census and time-to-solution comparison.

Run:  PYTHONPATH=src:. python examples/lab_experiment.py [--n 128] [--t 100]
"""
import argparse
import sys
import time

import numpy as np

sys.path.insert(0, ".")
from benchmarks.common import regime_iinj, soma_model  # noqa: E402
from benchmarks.lab_experiment_fig8 import PCTS  # noqa: E402
from repro.core import bdf, exec_bsp, exec_fap, network  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--t", type=float, default=100.0, help="biological ms")
    args = ap.parse_args()

    model = soma_model()
    net = network.make_network(args.n, k_in=16, seed=11)
    rng = np.random.default_rng(0)
    names = list(PCTS)
    assign = rng.choice(len(names), size=args.n,
                        p=np.array(list(PCTS.values())) / sum(PCTS.values()))
    iinj = np.empty(args.n)
    for i, name in enumerate(names):
        m = assign == i
        if m.any():
            iinj[m] = regime_iinj(int(m.sum()), name, seed=i)
    print(f"{args.n} neurons, {net.n_edges} synapses, regimes: "
          + ", ".join(f"{n}={int((assign==i).sum())}"
                      for i, n in enumerate(names)))

    def timed(make):
        import jax
        runner = make()
        jax.block_until_ready(runner())
        t0 = time.time()
        out = jax.block_until_ready(runner())
        res = out if isinstance(out, exec_bsp.RunResult) else out[0]
        return res, time.time() - t0

    r2a, t2a = timed(lambda: exec_bsp.make_bsp_fixed_runner(
        model, net, iinj, args.t, method="derivimplicit"))
    opts = bdf.BDFOptions(atol=1e-3)
    r2c, t2c = timed(lambda: exec_fap.make_fap_vardt_runner(
        model, net, iinj, args.t, opts=opts))
    reg, teg = timed(lambda: exec_fap.make_fap_vardt_runner(
        model, net, iinj, args.t, opts=opts, eg_window=0.025))

    spikes = int(r2c.rec.count.sum())
    ev_hz = int(r2c.n_events) / args.n / (args.t * 1e-3)
    print(f"\nactivity: {spikes} spikes, {int(r2c.n_events)} synaptic events "
          f"(mean {ev_hz:.1f} Hz/neuron — paper measured 94 Hz; "
          f"<1 kHz break-even: {ev_hz < 1000})")
    print(f"\n2a  BSP fixed implicit : {int(r2a.n_steps):8d} steps  "
          f"{t2a:6.2f}s wall")
    print(f"2c  FAP vardt precise  : {int(r2c.n_steps):8d} steps  "
          f"{t2c:6.2f}s wall  (steps {int(r2a.n_steps)/int(r2c.n_steps):.1f}x"
          f" fewer, wall {t2a/max(t2c,1e-9):.2f}x)")
    print(f"2c  FAP vardt EG-full  : {int(reg.n_steps):8d} steps  "
          f"{teg:6.2f}s wall  (resets {int(r2c.n_resets)} -> {int(reg.n_resets)})")
    assert not bool(r2c.failed) and int(r2c.dropped) == 0


if __name__ == "__main__":
    main()

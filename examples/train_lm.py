"""End-to-end LM training driver on the shared substrate: a ~20M-parameter
smollm-family model for a few hundred steps with checkpointing + fault
tolerance (the CPU-scaled stand-in for the 100M-class run; pass bigger
--d-model/--n-layers on real hardware — the code path is identical to the
full assigned configs).

Run:  PYTHONPATH=src python examples/train_lm.py
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.exit(main([
        "--arch", "smollm-135m",
        "--d-model", "192", "--n-layers", "6", "--vocab", "2048",
        "--steps", "200", "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_train_lm", "--save-every", "50",
    ]))

"""arctic-480b: 128-expert top-2 MoE with dense residual FFN
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    n_experts=128, top_k=2, dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base",
)

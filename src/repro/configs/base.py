"""Architecture + run configuration.

One ``ArchConfig`` per assigned architecture (see sibling modules); exact
hyper-parameters from the assignment table.  ``reduced()`` derives the
small-CPU smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1.0e4
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False     # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    shared_attn_every: int = 0       # zamba2: shared attn block period
    # modality stubs
    n_patches: int = 0               # vlm: prepended patch embeddings
    enc_layers: int = 0              # audio: encoder depth
    enc_frames: int = 0              # audio: encoder frame count
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # notes
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        return replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            shared_attn_every=2 if self.shared_attn_every else 0,
            n_patches=8 if self.n_patches else 0,
            enc_layers=min(self.enc_layers, 2),
            enc_frames=16 if self.enc_frames else 0,
            param_dtype="float32",
            compute_dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""

"""internvl2-1b: InternViT (stub patch embeds) + InternLM2 backbone
[arXiv:2404.16821; hf].  VLM frontend is a STUB per the assignment:
input_specs supplies precomputed patch embeddings [B, 256, d]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655,
    n_patches=256,
    source="arXiv:2404.16821",
)

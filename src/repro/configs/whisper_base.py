"""whisper-base: enc-dec with conv frontend STUB (input_specs supplies
log-mel frame embeddings [B, 1500, d]) [arXiv:2212.04356; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865,
    enc_layers=6, enc_frames=1500,
    source="arXiv:2212.04356",
)

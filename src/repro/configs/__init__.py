"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shape_applicable
from repro.configs.deepseek_7b import CONFIG as deepseek_7b
from repro.configs.qwen2_72b import CONFIG as qwen2_72b
from repro.configs.internlm2_20b import CONFIG as internlm2_20b
from repro.configs.smollm_135m import CONFIG as smollm_135m
from repro.configs.arctic_480b import CONFIG as arctic_480b
from repro.configs.qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b
from repro.configs.internvl2_1b import CONFIG as internvl2_1b
from repro.configs.rwkv6_7b import CONFIG as rwkv6_7b
from repro.configs.whisper_base import CONFIG as whisper_base
from repro.configs.zamba2_7b import CONFIG as zamba2_7b

ARCHS = {
    c.name: c for c in [
        deepseek_7b, qwen2_72b, internlm2_20b, smollm_135m, arctic_480b,
        qwen3_moe_30b_a3b, internvl2_1b, rwkv6_7b, whisper_base, zamba2_7b,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]

"""Deterministic sharded synthetic data pipeline.

Design goals (DESIGN.md §6):
  * deterministic as a pure function of (seed, step) — restart/elastic-resume
    reproduces the exact token stream with no stored state beyond the cursor,
  * shardable — each data-parallel group materialises only its slice,
  * checkpointable — the cursor is one integer in the train checkpoint.

Tokens follow a Zipfian unigram draw with Markov structure (repeat/copy
patterns) so losses are non-degenerate and learnable; labels are
next-token-shifted.  Family-specific stub inputs (audio frames, VLM patches)
are generated alongside.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


def _tokens_for_step(seed: int, step: int, batch: int, seq: int, vocab: int):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    # zipf-ish unigram via exponential of exponential
    u = jax.random.uniform(k1, (batch, seq), minval=1e-6, maxval=1.0)
    zipf = jnp.clip((u ** (-0.6) - 1.0) * vocab / 50.0, 0, vocab - 1)
    toks = zipf.astype(jnp.int32)
    # markov structure: with p=0.3 copy the previous token (learnable signal)
    copy = jax.random.uniform(k2, (batch, seq)) < 0.3
    rolled = jnp.roll(toks, 1, axis=1)
    toks = jnp.where(copy, rolled, toks)
    return toks


@dataclass
class DataPipeline:
    cfg: ArchConfig
    shape: ShapeConfig
    seed: int = 0
    step: int = 0                      # cursor — checkpointed

    def next_batch(self) -> dict:
        b = make_batch(self.cfg, self.shape, self.seed, self.step)
        self.step += 1
        return b

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, d: dict):
        self.seed = int(d["seed"])
        self.step = int(d["step"])


def make_batch(cfg: ArchConfig, shape: ShapeConfig, seed: int, step: int,
               batch_override: Optional[int] = None) -> dict:
    B = batch_override or shape.global_batch
    S = shape.seq_len
    toks = _tokens_for_step(seed, step, B, S + 1, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 7919), step)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model), jnp.float32) * 0.02
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.float32) * 0.02
    return batch

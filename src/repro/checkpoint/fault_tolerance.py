"""Fault tolerance for long training AND simulation runs (DESIGN.md §6).

``RestartManager`` wraps the training loop:
  * periodic checkpoints (params, optimizer, data cursor, RNG) with pruning,
  * automatic resume from the latest complete checkpoint on (re)start,
  * NaN/Inf-loss quarantine: restore last checkpoint and skip the poisoned
    data step (a common real-cluster failure mode),
  * failure injection hooks for tests (simulated preemption).

``FaultPlan`` generalises the same failure-injection hooks from
loss-driven training steps to *simulation rounds*: the FAP drivers
(``exec_common.run_checkpointed`` behind ``run_fap_spmd`` and the
single-host vardt runners) consume it at round boundaries — kill at round
k (``SimulatedFailure``, tests catch it and resume), poison one lane's
BDF history with a non-finite value (the health watchdog must detect and
roll back, never silently propagate), or an arbitrary ``mutate`` hook for
scenario-specific corruption.

``StragglerMonitor`` tracks per-step wall time over an O(window) deque
(long FAP runs make millions of rounds — an unbounded list would leak)
and flags outliers; on real pods the hook triggers re-sharding away from
the slow host — here its ``stats()`` ride ``RunResult.health``.  Note the
paper's FAP execution model is itself the structural answer to stragglers
for the simulation workload: there is no barrier to straggle on (paper
§4.3).
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Optional

import numpy as np

from repro.checkpoint.checkpoint import (latest_step, prune_checkpoints,
                                         restore_checkpoint, save_checkpoint)


@dataclass
class StragglerMonitor:
    """``window`` and the regression ``threshold`` (flag a step slower than
    threshold x the rolling window median) are constructor knobs — the
    checkpointed drivers accept a configured monitor via
    ``run_checkpointed(straggler=...)`` and ``repro.serve`` exposes both
    on the service so shedding decisions are tunable AND observable
    (``stats()`` rides ``RunResult.health`` / ``ServeResult.health``)."""
    window: int = 32
    threshold: float = 2.5
    times: Any = None          # deque(maxlen=window): O(window) memory
    flagged: int = 0
    recorded: int = 0          # total steps seen (the deque forgets)
    flags: Any = None          # deque(maxlen=window) of recent verdicts

    def __post_init__(self):
        if self.times is None:
            self.times = deque(maxlen=self.window)
        elif not isinstance(self.times, deque):
            self.times = deque(self.times, maxlen=self.window)
        if self.flags is None:
            self.flags = deque(maxlen=self.window)
        elif not isinstance(self.flags, deque):
            self.flags = deque(self.flags, maxlen=self.window)

    def record(self, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.times.append(dt)
        self.recorded += 1
        hist = list(self.times)
        if len(hist) < 8:
            self.flags.append(False)
            return False
        med = float(np.median(hist[:-1]))
        slow = dt > self.threshold * med
        self.flags.append(slow)
        if slow:
            self.flagged += 1
        return slow

    def sustained(self, frac: float = 0.25, min_steps: int = 8) -> bool:
        """Sustained round-time regression: at least ``frac`` of the last
        ``window`` recorded steps were flagged (and enough were seen to
        mean anything).  The overload-shedding trigger in ``repro.serve``
        — one slow step is noise, a quarter of the window is a regime."""
        hist = list(self.flags)
        if len(hist) < min_steps:
            return False
        return sum(hist) >= frac * len(hist)

    def stats(self) -> dict:
        """Host-side summary for ``RunResult.health`` (floats/ints only)."""
        hist = list(self.times)
        return {"recorded": self.recorded, "flagged": self.flagged,
                "window": self.window, "threshold": self.threshold,
                "sustained": self.sustained(),
                "window_median_s": float(np.median(hist)) if hist else 0.0,
                "window_max_s": float(max(hist)) if hist else 0.0}


@dataclass
class FaultPlan:
    """Deterministic fault injection at simulation-round boundaries.

    fail_at_round:   raise ``SimulatedFailure`` when the round counter hits
                     this value (simulated preemption — the process "dies";
                     tests catch it and relaunch with ``resume=True``).
    poison_at_round: overwrite lane ``poison_lane``'s BDF history (``zn``)
                     with ``poison_value`` at this round boundary, once —
                     the health watchdog must detect the non-finite state,
                     roll back to the last checkpoint and retry (the retry
                     is clean, so the run completes identically).
    mutate:          arbitrary hook ``(round_idx, carry) -> carry`` applied
                     every round before stepping — scenario-specific
                     corruption (repeated poisoning, queue tampering, ...).
    poison_tenant:   multi-tenant target (``repro.serve``): the *request
                     id* whose lane gets the poison.  The injection waits
                     until that tenant is admitted and running (rounds are
                     service rounds there), and ``poison_lane`` indexes
                     the neuron within the tenant's own network.  The
                     single-run drivers ignore this field.
    """
    fail_at_round: Optional[int] = None
    poison_at_round: Optional[int] = None
    poison_lane: int = 0
    poison_value: float = float("nan")
    mutate: Optional[Callable] = None
    poison_tenant: Optional[int] = None


@dataclass(frozen=True)
class ExponentialBackoff:
    """Bounded exponential retry schedule, in *scheduler rounds* (the
    serving layer's unit of time) — the ``RestartManager`` NaN-quarantine
    pattern factored out so training restarts and per-tenant simulation
    retries share one policy.  Retry ``a`` (1-based) waits
    ``min(cap, base * factor**(a-1))`` rounds; after ``max_retries``
    failed attempts the caller must evict/escalate (never loop silently).
    """
    base: int = 2
    factor: float = 2.0
    cap: int = 32
    max_retries: int = 3

    def delay(self, attempt: int) -> int:
        """Rounds to wait before retry ``attempt`` (1-based), capped."""
        if attempt < 1:
            raise ValueError("attempt is 1-based")
        return int(min(self.cap, self.base * self.factor ** (attempt - 1)))

    def budget(self) -> int:
        """Total backoff rounds across the full retry budget — the bound
        the admission-queue property tests assert against."""
        return sum(self.delay(a) for a in range(1, self.max_retries + 1))


@dataclass
class RestartManager:
    ckpt_dir: str
    save_every: int = 50
    keep: int = 3
    max_nan_retries: int = 3

    def resume_or_init(self, init_fn: Callable[[], Any]):
        """Returns (state_tree, extras, start_step)."""
        step = latest_step(self.ckpt_dir)
        if step is None:
            return init_fn(), {}, 0
        like = init_fn()
        state, extras = restore_checkpoint(self.ckpt_dir, step, like)
        return state, extras, step

    def run(self, state, start_step: int, n_steps: int,
            step_fn: Callable[[Any, int], tuple],
            data_state_fn: Callable[[], dict] = lambda: {},
            inject_failure_at: Optional[int] = None,
            log_every: int = 10, log_fn=print):
        """Drive the loop: state' , metrics = step_fn(state, step).

        metrics must contain "loss".  Returns (state, history).
        """
        monitor = StragglerMonitor()
        history = []
        nan_retries = 0
        step = start_step
        while step < n_steps:
            if inject_failure_at is not None and step == inject_failure_at:
                inject_failure_at = None
                raise SimulatedFailure(step)
            t0 = time.time()
            new_state, metrics = step_fn(state, step)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if not math.isfinite(loss):
                nan_retries += 1
                if nan_retries > self.max_nan_retries:
                    raise RuntimeError(f"non-finite loss at step {step}, "
                                       f"retries exhausted")
                last = latest_step(self.ckpt_dir)
                log_fn(f"[ft] non-finite loss at step {step}; restoring "
                       f"step {last} and skipping batch")
                if last is not None:
                    state, _ = restore_checkpoint(self.ckpt_dir, last, state)
                step += 1                      # skip the poisoned batch
                continue
            nan_retries = 0
            state = new_state
            if monitor.record(dt):
                log_fn(f"[ft] straggler step {step}: {dt:.3f}s "
                       f"(median ~{monitor.stats()['window_median_s']:.3f}s)")
            history.append({"step": step, "loss": loss, "time_s": dt})
            if log_every and step % log_every == 0:
                log_fn(f"step {step}: loss={loss:.4f} ({dt:.2f}s)")
            step += 1
            if self.save_every and step % self.save_every == 0:
                save_checkpoint(self.ckpt_dir, step, state,
                                extras=data_state_fn())
                prune_checkpoints(self.ckpt_dir, self.keep)
        save_checkpoint(self.ckpt_dir, step, state, extras=data_state_fn())
        prune_checkpoints(self.ckpt_dir, self.keep)
        return state, history


class SimulatedFailure(RuntimeError):
    """Raised by failure injection; tests catch it and resume."""

    def __init__(self, step):
        super().__init__(f"simulated preemption at step {step}")
        self.step = step

"""Sharded checkpoint save/restore with atomic commit and integrity manifest.

Layout of one checkpoint::

    <dir>/step_000123/
        manifest.json      # tree structure, shapes, dtypes, sizes, extras
        arr_00000.npy ...  # one file per leaf (host-local full array here;
                           # in a multi-host deployment each host writes its
                           # process-local shards — path layout is identical)

Atomicity: everything is written into ``step_X.tmp`` and renamed once the
manifest (written LAST) is on disk — a crashed save can never be mistaken
for a complete checkpoint.  ``restore_checkpoint`` optionally reshards onto
a different mesh (elastic resume, DESIGN.md §6).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extras: Optional[dict] = None) -> str:
    """tree: pytree of arrays (params, opt state, ...); extras: JSON-ables."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    entries = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        entries.append({"file": fname, "shape": list(arr.shape),
                        "dtype": str(arr.dtype), "bytes": int(arr.nbytes)})
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "entries": entries,
        "extras": extras or {},
        "total_bytes": int(sum(e["bytes"] for e in entries)),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like_tree: Any,
                       shardings: Any = None):
    """Restore into the structure of ``like_tree``; optionally device_put
    each leaf with the given sharding tree (elastic resharding)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like_tree)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves)}")
    out = []
    for i, (entry, like) in enumerate(zip(manifest["entries"], leaves)):
        arr = np.load(os.path.join(path, entry["file"]))
        if int(arr.nbytes) != entry["bytes"]:
            raise IOError(f"integrity failure on {entry['file']}")
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"leaf {i} shape {arr.shape} != expected {tuple(like.shape)}")
        out.append(arr.astype(like.dtype))
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        out = [jax.device_put(a, s) for a, s in zip(out, sh_leaves)]
    else:
        out = [jax.numpy.asarray(a) for a in out]
    return treedef.unflatten(out), manifest["extras"]


def prune_checkpoints(ckpt_dir: str, keep: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)

"""Sharded checkpoint save/restore with atomic commit and integrity manifest.

Layout of one checkpoint::

    <dir>/step_000123/
        manifest.json      # tree structure, shapes, dtypes, sizes, crc32s
        arr_00000.npy ...  # one file per leaf (host-local full array here;
                           # in a multi-host deployment each host writes its
                           # process-local shards — path layout is identical)

Atomicity: everything is written into ``step_X.tmp`` and renamed once the
manifest (written LAST) is on disk — a crashed save can never be mistaken
for a complete checkpoint.  ``restore_checkpoint`` optionally reshards onto
a different mesh (elastic resume, DESIGN.md §6).

Integrity is verified on restore, never assumed: every leaf carries a
crc32 of its bytes in the manifest (bit rot and truncated writes fail
loudly), and the stored pytree structure string must match the restore
target's (a mismatched treedef would otherwise restore leaf-by-leaf into
the wrong structure).  Directory handling is crash-robust: stray entries
that are not ``step_NNNNNNNN`` checkpoints are ignored rather than
tripping the step parser, and orphaned ``*.tmp`` dirs left by a crashed
save are garbage-collected (the atomic rename means any ``.tmp`` entry is
garbage by construction — single-writer-per-directory assumed).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import zlib
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _step_of(name: str) -> Optional[int]:
    """Step number of a checkpoint dir entry; None for anything that is
    not a complete ``step_NNNNNNNN`` name (stray files, ``.tmp`` dirs,
    hand-made ``step_old`` backups, ...)."""
    m = _STEP_RE.match(name)
    return int(m.group(1)) if m else None


def gc_incomplete(ckpt_dir: str) -> list:
    """Remove orphaned ``*.tmp`` dirs left behind by a crashed save.

    The atomic-commit protocol renames ``step_X.tmp`` -> ``step_X`` only
    after the manifest is fsynced, so any surviving ``.tmp`` entry is an
    incomplete save and can never be a restore target.  Returns the
    removed names (detected, never silent)."""
    removed = []
    if not os.path.isdir(ckpt_dir):
        return removed
    for name in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, name)
        if name.endswith(".tmp") and os.path.isdir(full):
            shutil.rmtree(full, ignore_errors=True)
            removed.append(name)
    return removed


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extras: Optional[dict] = None) -> str:
    """tree: pytree of arrays (params, opt state, ...); extras: JSON-ables."""
    final = step_path(ckpt_dir, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    entries = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        entries.append({"file": fname, "shape": list(arr.shape),
                        "dtype": str(arr.dtype), "bytes": int(arr.nbytes),
                        "crc32": int(zlib.crc32(arr.tobytes()))})
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "entries": entries,
        "extras": extras or {},
        "total_bytes": int(sum(e["bytes"] for e in entries)),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        step = _step_of(name)
        if step is not None and \
                os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(step)
    return max(steps) if steps else None


def read_manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def load_leaf(path: str, entry: dict) -> np.ndarray:
    """Load one leaf array, verifying byte count and (when present in the
    manifest — older checkpoints predate it) the stored crc32."""
    arr = np.load(os.path.join(path, entry["file"]))
    if int(arr.nbytes) != entry["bytes"]:
        raise IOError(f"integrity failure on {entry['file']}: "
                      f"{arr.nbytes} bytes != manifest {entry['bytes']}")
    crc = entry.get("crc32")
    if crc is not None and int(zlib.crc32(arr.tobytes())) != crc:
        raise IOError(f"integrity failure on {entry['file']}: crc32 mismatch")
    return arr


def restore_checkpoint(ckpt_dir: str, step: int, like_tree: Any,
                       shardings: Any = None):
    """Restore into the structure of ``like_tree``; optionally device_put
    each leaf with the given sharding tree (elastic resharding)."""
    path = step_path(ckpt_dir, step)
    manifest = read_manifest(path)
    leaves, treedef = _flatten(like_tree)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves)}")
    stored_td = manifest.get("treedef")
    if stored_td is not None and stored_td != str(treedef):
        raise ValueError(
            "checkpoint pytree structure does not match the restore target:\n"
            f"  stored:   {stored_td}\n  expected: {treedef}")
    out = []
    for i, (entry, like) in enumerate(zip(manifest["entries"], leaves)):
        arr = load_leaf(path, entry)
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"leaf {i} shape {arr.shape} != expected {tuple(like.shape)}")
        out.append(arr.astype(like.dtype))
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        out = [jax.device_put(a, s) for a, s in zip(out, sh_leaves)]
    else:
        out = [jax.numpy.asarray(a) for a in out]
    return treedef.unflatten(out), manifest["extras"]


# --- per-tenant checkpoints (repro.serve) ----------------------------------
#
# The multi-tenant service snapshots each tenant's SimCarry *slice*
# independently: one tenant's rollback must never rewind a neighbour, so
# each request id gets its own checkpoint directory (same atomic-commit +
# manifest format — ``tenant_000007/step_00000042/``).  The tenant id is
# also recorded in the manifest extras so a directory listing alone can be
# audited against the service's accounting.

def tenant_dir(ckpt_dir: str, tenant: int) -> str:
    return os.path.join(ckpt_dir, f"tenant_{int(tenant):06d}")


def save_tenant_checkpoint(ckpt_dir: str, tenant: int, step: int, tree: Any,
                           extras: Optional[dict] = None) -> str:
    ex = dict(extras or {})
    ex["tenant"] = int(tenant)
    return save_checkpoint(tenant_dir(ckpt_dir, tenant), step, tree, extras=ex)


def latest_tenant_step(ckpt_dir: str, tenant: int) -> Optional[int]:
    return latest_step(tenant_dir(ckpt_dir, tenant))


def restore_tenant_checkpoint(ckpt_dir: str, tenant: int, step: int,
                              like_tree: Any, shardings: Any = None):
    return restore_checkpoint(tenant_dir(ckpt_dir, tenant), step, like_tree,
                              shardings=shardings)


def list_tenants(ckpt_dir: str) -> list:
    """Tenant ids that have at least one complete checkpoint on disk."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.match(r"^tenant_(\d{6})$", name)
        if m and latest_step(os.path.join(ckpt_dir, name)) is not None:
            out.append(int(m.group(1)))
    return sorted(out)


def prune_tenant_checkpoints(ckpt_dir: str, tenant: int, keep: int = 2):
    prune_checkpoints(tenant_dir(ckpt_dir, tenant), keep)


def prune_checkpoints(ckpt_dir: str, keep: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    gc_incomplete(ckpt_dir)
    steps = sorted(s for n in os.listdir(ckpt_dir)
                   if (s := _step_of(n)) is not None)
    for s in steps[:-keep]:
        shutil.rmtree(step_path(ckpt_dir, s), ignore_errors=True)

from repro.checkpoint.checkpoint import (gc_incomplete, latest_step,  # noqa: F401
                                         prune_checkpoints,
                                         restore_checkpoint, save_checkpoint)
from repro.checkpoint.fault_tolerance import (FaultPlan, RestartManager,  # noqa: F401
                                              SimulatedFailure,
                                              StragglerMonitor)

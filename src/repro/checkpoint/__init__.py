from repro.checkpoint.checkpoint import (gc_incomplete, latest_step,  # noqa: F401
                                         latest_tenant_step, list_tenants,
                                         prune_checkpoints,
                                         prune_tenant_checkpoints,
                                         restore_checkpoint,
                                         restore_tenant_checkpoint,
                                         save_checkpoint,
                                         save_tenant_checkpoint, tenant_dir)
from repro.checkpoint.fault_tolerance import (ExponentialBackoff,  # noqa: F401
                                              FaultPlan, RestartManager,
                                              SimulatedFailure,
                                              StragglerMonitor)

from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,  # noqa: F401
                                         save_checkpoint)
from repro.checkpoint.fault_tolerance import RestartManager, StragglerMonitor  # noqa: F401

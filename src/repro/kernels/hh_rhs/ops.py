"""Public jit'd wrapper for the fused HH RHS Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret
from repro.kernels.hh_rhs.hh_rhs import BN_DEFAULT, hh_rhs_pallas


@partial(jax.jit, static_argnames=("block_n",))
def hh_rhs_batched(area, v, m, h, n, block_n: int = BN_DEFAULT):
    """Fused RHS with automatic batch padding. v,m,h,n: [N, C]."""
    N, C = v.shape
    n_pad = (-N) % block_n
    if n_pad:
        pad = lambda x, c: jnp.concatenate(
            [x, jnp.full((n_pad, C), c, x.dtype)], axis=0)
        v, m, h, n = pad(v, -65.0), pad(m, 0.5), pad(h, 0.5), pad(n, 0.5)
    outs = hh_rhs_pallas(area, v, m, h, n, block_n=block_n,
                         interpret=use_interpret())
    return tuple(o[:N] for o in outs)

"""Pallas TPU kernel: fused HH gating rates + ionic currents (the CVODE f).

This is the per-step mechanism hot-spot (NEURON spends the bulk of a step in
the channel state/current update — exp/div-heavy VPU work).  The kernel
fuses, for a tile of neurons x compartments:

    rates alpha/beta(V) -> (dm, dh, dn), ionic current i(V, m, h, n) and the
    conductance total g_tot (the Newton-diagonal term),

in a single VMEM pass over the state — one load of (v, m, h, n) and one store
of each output instead of ~10 separate HLO loops.

Layout: [BN, C] tiles — neurons on sublanes, compartments on lanes.
VMEM/block = 9 tiles * BN*C*4B; BN=256, C=64 -> ~2.4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import mechanisms as mech

BN_DEFAULT = 256


def _hh_rhs_kernel(area_ref, v_ref, m_ref, h_ref, n_ref,
                   dm_ref, dh_ref, dn_ref, i_ref, g_ref):
    v = v_ref[...]
    m = m_ref[...]
    h = h_ref[...]
    n = n_ref[...]
    area = area_ref[...]                       # [1, C] broadcast over neurons
    dm, dh, dn = mech.gate_derivs(v, m, h, n)
    g_na, g_k, g_l = mech.channel_conductances(area, m, h, n)
    i_ion = g_na * (v - mech.ENA) + g_k * (v - mech.EK) + g_l * (v - mech.EL)
    dm_ref[...] = dm
    dh_ref[...] = dh
    dn_ref[...] = dn
    i_ref[...] = i_ion
    g_ref[...] = g_na + g_k + g_l


def hh_rhs_pallas(area, v, m, h, n, *, block_n: int = BN_DEFAULT,
                  interpret: bool = True):
    """area: [C]; v,m,h,n: [N, C] -> (dm, dh, dn, i_ion, g_tot) each [N, C]."""
    N, C = v.shape
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    dt = v.dtype
    out_shape = tuple(jax.ShapeDtypeStruct((N, C), dt) for _ in range(5))
    tile = pl.BlockSpec((block_n, C), lambda i: (i, 0))
    return pl.pallas_call(
        _hh_rhs_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, C), lambda i: (0, 0))] + [tile] * 4,
        out_specs=(tile,) * 5,
        out_shape=out_shape,
        interpret=interpret,
    )(area.reshape(1, C).astype(dt), v, m, h, n)

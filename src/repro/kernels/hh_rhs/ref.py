"""Pure-jnp oracle for the fused HH RHS kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import mechanisms as mech


def hh_rhs_ref(area, v, m, h, n):
    """area: [C]; v,m,h,n: [N, C] -> (dm, dh, dn, i_ion, g_tot)."""
    dm, dh, dn = mech.gate_derivs(v, m, h, n)
    g_na, g_k, g_l = mech.channel_conductances(area[None, :], m, h, n)
    i_ion = g_na * (v - mech.ENA) + g_k * (v - mech.EK) + g_l * (v - mech.EL)
    return dm, dh, dn, i_ion, g_na + g_k + g_l

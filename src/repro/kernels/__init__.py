"""Pallas TPU kernels for the compute hot-spots.

Layout: one subpackage per kernel with
  <name>.py  - pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py     - jit'd public wrapper (auto interpret-mode off-TPU)
  ref.py     - pure-jnp oracle used by the allclose test sweeps

Kernels:
  hines       - batched Hines tree-tridiagonal solve (the per-Newton-iteration
                linear solve; NEURON's core numeric kernel)
  hh_rhs      - fused HH gating-rate + ionic-current evaluation (the CVODE f)
  attention   - flash attention (causal/GQA) for the LM architecture zoo
  event_wheel - fused FAP horizon min-reduce + runnable mask + sort-free
                earliest-K selection (the scheduler round's notification half;
                pairs with the repro.sched event-wheel queue)
"""

import jax


def use_interpret() -> bool:
    """Pallas interpret mode everywhere except on real TPU."""
    return jax.default_backend() != "tpu"

"""Pure-jnp oracles for the fused horizon/selection kernel."""
from __future__ import annotations

import jax.numpy as jnp


def horizon_score_ref(cand, t_clock, *, t_end: float, horizon_cap: float,
                      eps: float = 1e-12):
    """cand: f64[K, N] = t_clock[pre] + delay in by-post layout.

    Returns (horizon[N], score[N]): the per-neuron dependency horizon
    (min over in-edges, clamped at t_end and t_clock + horizon_cap) and
    the scheduler score (clock if runnable else +inf).
    """
    hor = jnp.minimum(cand.min(axis=0), t_end)
    hor = jnp.minimum(hor, t_clock + horizon_cap)
    runnable = t_clock < hor - eps
    return hor, jnp.where(runnable, t_clock, jnp.inf)


def select_earliest_ref(score, k: int):
    """Sort-based earliest-K oracle (the dense scheduler path): select all
    entries with score <= k-th smallest (ties included)."""
    kth = jnp.sort(score)[min(k, score.shape[0]) - 1]
    return jnp.logical_and(jnp.isfinite(score), score <= kth)


def compact_ids_ref(mask, cap: int):
    """Pure-jnp gather-id compaction: cumsum ranks + one masked scatter
    (O(N), sort-free).  Returns (ids i32[cap] — indices of the first
    ``cap`` set lanes in index order, sentinel N for empty slots; count
    i32 — total set lanes, may exceed cap)."""
    n = mask.shape[0]
    msk = mask.astype(jnp.int32)
    csum = jnp.cumsum(msk)
    pos = csum - msk
    slot = jnp.where(jnp.logical_and(msk == 1, pos < cap), pos, cap)
    ids = jnp.full((cap,), n, jnp.int32).at[slot].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    return ids, csum[-1] if n else jnp.zeros((), jnp.int32)


def compact_gather_ref(mask, table, cap: int, fill: int):
    """Pure-jnp oracle for the compact-and-gather kernel: gather-id
    compaction (``compact_ids_ref``) followed by one XLA row gather of the
    static table.  Returns (ids i32[cap], rows i32[cap, MO] — table[ids]
    with ``fill`` in empty slots, count i32)."""
    n = mask.shape[0]
    ids, cnt = compact_ids_ref(mask, cap)
    rows = jnp.where((ids < n)[:, None],
                     table[jnp.minimum(ids, n - 1)], fill).astype(jnp.int32)
    return ids, rows, cnt


def segment_rank_ref(key, max_rank: int):
    """O(E^2) pairwise oracle for the segment-ranking kernel: rank[j] =
    |{i < j : key[i] == key[j]}| clipped at ``max_rank``."""
    E = key.shape[0]
    same = key[:, None] == key[None, :]
    earlier = jnp.arange(E)[None, :] < jnp.arange(E)[:, None]
    return jnp.minimum(jnp.sum(jnp.logical_and(same, earlier), axis=1),
                       max_rank).astype(jnp.int32)


def compact_rows_ref(mask, values, *, cap: int):
    """Pure-jnp oracle for the spike-compaction kernel: cumsum ranks + a
    masked scatter (still sort-free — the dense-queue argsort is the thing
    being avoided, and the test census checks this path too)."""
    D, M = mask.shape
    msk = mask.astype(jnp.int32)
    csum = jnp.cumsum(msk, axis=-1)
    pos = csum - msk
    total = csum[:, -1]
    rows = jnp.broadcast_to(jnp.arange(D, dtype=jnp.int32)[:, None], (D, M))
    col = jnp.where(jnp.logical_and(msk == 1, pos < cap), pos, cap)
    idx = jnp.full((D, cap), M, jnp.int32).at[rows, col].set(
        jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32)[None, :], (D, M)),
        mode="drop")
    vals = jnp.zeros((D, cap), values.dtype).at[rows, col].set(
        jnp.broadcast_to(values, (D, M)), mode="drop")
    return idx, vals, total

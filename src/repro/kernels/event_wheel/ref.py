"""Pure-jnp oracles for the fused horizon/selection kernel."""
from __future__ import annotations

import jax.numpy as jnp


def horizon_score_ref(cand, t_clock, *, t_end: float, horizon_cap: float,
                      eps: float = 1e-12):
    """cand: f64[K, N] = t_clock[pre] + delay in by-post layout.

    Returns (horizon[N], score[N]): the per-neuron dependency horizon
    (min over in-edges, clamped at t_end and t_clock + horizon_cap) and
    the scheduler score (clock if runnable else +inf).
    """
    hor = jnp.minimum(cand.min(axis=0), t_end)
    hor = jnp.minimum(hor, t_clock + horizon_cap)
    runnable = t_clock < hor - eps
    return hor, jnp.where(runnable, t_clock, jnp.inf)


def select_earliest_ref(score, k: int):
    """Sort-based earliest-K oracle (the dense scheduler path): select all
    entries with score <= k-th smallest (ties included)."""
    kth = jnp.sort(score)[min(k, score.shape[0]) - 1]
    return jnp.logical_and(jnp.isfinite(score), score <= kth)

"""Public jit'd wrappers: fused horizon + sort-free earliest-K selection.

``fused_horizon_select`` replaces the scheduler round's scatter-min +
clamp + runnable chain with one Pallas pass, and — when k_select > 0 —
replaces ``jnp.sort(score)[k-1]`` with ``select_threshold``'s bisection on
counts: the jaxpr of the whole selection path carries no sort primitive.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret
from repro.kernels.event_wheel.event_wheel import (BN_DEFAULT,
                                                   compact_gather_pallas,
                                                   compact_ids_pallas,
                                                   compact_rows_pallas,
                                                   horizon_score_pallas,
                                                   segment_rank_pallas)


def select_threshold(score, k: int, n_iters: int = 48):
    """Smallest bisection point tau with count(score <= tau) >= k.

    ``score <= tau`` then selects the K earliest runnable neurons (ties and
    entries within the bisection resolution, (max-min)/2^n_iters, are all
    included — with n_iters = 48 that is below f64 time resolution for any
    millisecond-scale window, so the selection matches the sort-based kth
    threshold).  Fewer than k finite scores -> tau = max (select all), the
    same semantics as ``sort(score)[k-1] = +inf``.  Reductions only.
    """
    finite = jnp.isfinite(score)
    any_run = finite.any()
    lo = jnp.min(jnp.where(finite, score, jnp.inf))
    hi = jnp.max(jnp.where(finite, score, -jnp.inf))

    def body(_, c):
        lo, hi = c
        mid = 0.5 * (lo + hi)
        enough = jnp.sum(score <= mid) >= k
        return jnp.where(enough, lo, mid), jnp.where(enough, mid, hi)

    lo, hi = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
    return jnp.where(any_run, hi, jnp.inf)


@partial(jax.jit, static_argnames=("t_end", "horizon_cap", "k_select",
                                  "n_iters", "block_n"))
def fused_horizon_select(t_clock, pre_byk, delay_byk, *, t_end: float,
                         horizon_cap: float, k_select: int = 0,
                         n_iters: int = 48, block_n: int = BN_DEFAULT):
    """Fused scheduler-round notification half.

    t_clock: f64[N]; pre_byk: i32[K, N], delay_byk: f64[K, N] (the static
    by-post edge layout: column i holds neuron i's K in-edges).
    Returns (horizon[N], runnable bool[N]); with k_select > 0 the mask is
    restricted to the K earliest runnable neurons by threshold count.
    """
    K, N = pre_byk.shape
    cand = t_clock[pre_byk] + delay_byk        # one XLA gather
    n_pad = (-N) % block_n
    tc = t_clock
    if n_pad:
        cand = jnp.concatenate(
            [cand, jnp.full((K, n_pad), jnp.inf, cand.dtype)], axis=1)
        tc = jnp.concatenate(
            [tc, jnp.full((n_pad,), t_end, tc.dtype)])   # pad never runnable
    hor, score = horizon_score_pallas(cand, tc, t_end=t_end,
                                      horizon_cap=horizon_cap,
                                      block_n=block_n,
                                      interpret=use_interpret())
    hor, score = hor[:N], score[:N]
    runnable = jnp.isfinite(score)
    if k_select > 0:
        tau = select_threshold(score, k_select, n_iters=n_iters)
        runnable = jnp.logical_and(runnable, score <= tau)
    return hor, runnable


def spike_compact(mask, values, cap: int, *, impl: str = "pallas"):
    """Sort-free row-wise compaction of sparse spike streams into capped
    parcel buffers — the packer of the sparse spike-parcel transport
    (``repro.distributed.exchange``).

    mask: [D, M] (row d = the spikes destined for shard d); values: [D, M].
    Returns (idx i32[D, cap] — source column of each packed entry, sentinel M
    marks empty slots; vals f64[D, cap]; count i32[D] — kept per row, may
    exceed cap so callers can account drops).  ``impl="pallas"`` runs the
    cumsum-rank kernel (interpret off-TPU); ``"jnp"`` the scatter oracle.
    """
    if impl == "pallas":
        return compact_rows_pallas(mask, values, cap=cap,
                                   interpret=use_interpret())
    if impl == "jnp":
        from repro.kernels.event_wheel import ref
        return ref.compact_rows_ref(mask, values, cap=cap)
    raise ValueError(f"unknown spike_compact impl {impl!r}")


def compact_ids(mask, cap: int, *, impl: str = "auto",
                block_n: int = BN_DEFAULT):
    """Compact a bool[N] runnable mask into a gather-id list — the active
    set of the compact–step–scatter execution path (``batch="compact"``).

    Returns (ids i32[cap] — indices of the first ``cap`` set lanes in
    index order, sentinel N for empty slots; count i32 — total set lanes,
    which may exceed cap: the overflow rolls to a later dispatch, never
    drops).  The same cumsum-rank machinery as ``spike_compact``,
    generalised to emit the indices themselves: ``impl="pallas"`` runs the
    blocked [cap, BN] one-hot kernel, ``"jnp"`` the O(N) scatter oracle;
    ``"auto"`` picks pallas on real TPU and the scatter oracle elsewhere
    (interpret-mode grids walk the blocks in python).
    """
    if impl == "auto":
        impl = "jnp" if use_interpret() else "pallas"
    if impl == "jnp":
        from repro.kernels.event_wheel import ref
        return ref.compact_ids_ref(mask, cap)
    if impl != "pallas":
        raise ValueError(f"unknown compact_ids impl {impl!r}")
    (n,) = mask.shape
    n_pad = (-n) % block_n
    m = mask
    if n_pad:
        m = jnp.concatenate([m, jnp.zeros((n_pad,), m.dtype)])
    ids, cnt = compact_ids_pallas(m, cap=cap, block_n=block_n,
                                  interpret=use_interpret())
    return jnp.minimum(ids, n).astype(jnp.int32), cnt


def compact_gather(mask, table, cap: int, *, fill: int = None,
                   impl: str = "auto", block_n: int = BN_DEFAULT):
    """``compact_ids`` generalised to emit *gather rows*: compact a bool[N]
    mask into the first ``cap`` set lanes AND gather the rows of a static
    i32[N, MO] table for them in the same pass — the edge-index emitter of
    the compact fan-out path (``fanout="compact"``), where ``table`` is
    ``exec_common.out_edge_table`` and the emitted rows are the spiking
    lanes' out-edge ids.

    Returns (ids i32[cap] — set-lane indices in index order, sentinel N;
    rows i32[cap, MO] — table[ids], ``fill`` (default N, callers pass E)
    for empty slots; count i32 — total set lanes, may exceed cap: the
    caller must fall back, never drop).  ``impl="auto"`` picks the blocked
    Pallas kernel on real TPU and the scatter-oracle + XLA-gather path
    elsewhere.
    """
    (n,) = mask.shape
    if fill is None:
        fill = n
    if impl == "auto":
        impl = "jnp" if use_interpret() else "pallas"
    if impl == "jnp":
        from repro.kernels.event_wheel import ref
        return ref.compact_gather_ref(mask, table, cap, fill)
    if impl != "pallas":
        raise ValueError(f"unknown compact_gather impl {impl!r}")
    n_pad = (-n) % block_n
    m, tbl = mask, table
    if n_pad:
        m = jnp.concatenate([m, jnp.zeros((n_pad,), m.dtype)])
        tbl = jnp.concatenate(
            [tbl, jnp.zeros((n_pad, tbl.shape[1]), tbl.dtype)])
    ids, rows, cnt = compact_gather_pallas(m, tbl, cap=cap, fill=fill,
                                           block_n=block_n,
                                           interpret=use_interpret())
    return jnp.minimum(ids, n).astype(jnp.int32), rows, cnt


def segment_rank(key, n_keys: int, max_rank: int, *, impl: str = "auto",
                 block_e: int = 512, domain: str = "global"):
    """Rank of each event within its key group, in event-index order —
    the wheel's generic-insert slot ranking, dispatched (the ROADMAP
    follow-up from PR 1).

    ``impl="pallas"`` runs the pairwise [BE, BE] tile kernel: one VMEM
    pass, no per-round O(n_keys) key table; ``"scatter"`` the original
    ``max_rank``-round scatter-min (``sched.wheel.segment_rank``);
    ``"auto"`` picks pallas on real TPU, scatter elsewhere.  Ranks agree
    on all events with key < n_keys (invalid events differ: the scatter
    path parks them at ``max_rank``, the pairwise path ranks them among
    themselves — both are masked out by the insert's validity test).

    ``domain="batch"`` (the PR 5 follow-up) remaps the keys of a small
    batch to the dense [E] event domain before the scatter ranking: each
    valid event's key becomes the index of its group's first occurrence
    (a pairwise [E, E] first-occurrence argmax), so the per-round key
    table shrinks from O(n_keys + 1) = O(N*B) to O(E + 1) — the compact
    fan-out's cap-bounded edge batches stop allocating an N-proportional
    table per call off-TPU.  The remap is a bijection on key groups, so
    valid-event ranks are identical to the global domain; invalid events
    park at the E sentinel (rank ``max_rank``), as before.  The pallas
    path is already N-free and ignores the domain.
    """
    if impl == "auto":
        impl = "scatter" if use_interpret() else "pallas"
    if domain not in ("global", "batch"):
        raise ValueError(f"unknown segment_rank domain {domain!r}")
    if impl == "scatter":
        from repro.sched import wheel as wh
        if domain == "batch":
            (E,) = key.shape
            valid = key < n_keys
            same = key[:, None] == key[None, :]
            rep = jnp.argmax(same, axis=1).astype(key.dtype)
            key2 = jnp.where(valid, rep, E)
            return wh.segment_rank(key2, E, max_rank)
        return wh.segment_rank(key, n_keys, max_rank)
    if impl != "pallas":
        raise ValueError(f"unknown segment_rank impl {impl!r}")
    (E,) = key.shape
    e_pad = (-E) % block_e
    k = key
    if e_pad:
        # pad with a never-used key so pad ranks stay self-contained
        k = jnp.concatenate([k, jnp.full((e_pad,), n_keys + 1, key.dtype)])
    return segment_rank_pallas(k, max_rank=max_rank,
                               block_e=block_e,
                               interpret=use_interpret())[:E]


def by_post_layout(net):
    """Host-side static prep: the [K, N] by-post (pre, delay) layout the
    fused kernel consumes.  Requires the uniform grouped edge list that
    ``make_network`` emits (``sched.grouped_k``)."""
    import numpy as np

    from repro.sched import grouped_k
    k = grouped_k(net)
    if k is None:
        raise ValueError("fused horizon kernel needs a uniform by-post "
                         "edge layout (make_network's grouping)")
    n = int(net.n)
    pre_byk = jnp.asarray(np.asarray(net.pre).reshape(n, k).T)
    delay_byk = jnp.asarray(np.asarray(net.delay).reshape(n, k).T)
    return pre_byk, delay_byk

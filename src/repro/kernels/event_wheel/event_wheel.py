"""Pallas TPU kernel: fused FAP horizon + runnable-mask + scheduler score.

The scheduler round's notification half: horizon[i] = min over in-edges of
(t[pre] + delay), clamped at t_end and at the per-round cap, then the
runnable test and score formation (clock if runnable else +inf) — one VMEM
pass instead of four HLO loops over [N].

Layout mirrors the hines kernel's TPU transpose: neurons lie along the
128-wide lane dimension, the K in-edges along sublanes, so the min-reduce
is a full-width VPU column reduction over a [K, BN] tile.  The edge gather
(t_clock[pre] -> cand) happens outside the kernel as a single XLA gather —
the by-post edge layout makes the in-kernel work purely dense.

VMEM/block = (K + 3) * BN * 8B; K = 16, BN = 256 -> ~39 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN_DEFAULT = 256


def _horizon_kernel(cand_ref, t_clock_ref, hor_ref, score_ref, *,
                    t_end, horizon_cap, eps):
    cand = cand_ref[...]                       # [K, BN]
    t_c = t_clock_ref[...]                     # [1, BN]
    hor = jnp.minimum(jnp.min(cand, axis=0, keepdims=True), t_end)
    hor = jnp.minimum(hor, t_c + horizon_cap)
    runnable = t_c < hor - eps
    hor_ref[...] = hor
    score_ref[...] = jnp.where(runnable, t_c, jnp.inf)


def horizon_score_pallas(cand, t_clock, *, t_end: float, horizon_cap: float,
                         eps: float = 1e-12, block_n: int = BN_DEFAULT,
                         interpret: bool = True):
    """cand: [K, N] by-post candidates; t_clock: [N] -> (horizon[N], score[N]).

    N must be a multiple of block_n (the ops wrapper pads).
    """
    K, N = cand.shape
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    kernel = functools.partial(_horizon_kernel, t_end=t_end,
                               horizon_cap=horizon_cap, eps=eps)
    row = pl.BlockSpec((1, block_n), lambda i: (0, i))
    hor, score = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((K, block_n), lambda i: (0, i)), row],
        out_specs=(row, row),
        out_shape=(jax.ShapeDtypeStruct((1, N), cand.dtype),) * 2,
        interpret=interpret,
    )(cand, t_clock.reshape(1, N))
    return hor[0], score[0]

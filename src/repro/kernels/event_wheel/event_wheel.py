"""Pallas TPU kernel: fused FAP horizon + runnable-mask + scheduler score.

The scheduler round's notification half: horizon[i] = min over in-edges of
(t[pre] + delay), clamped at t_end and at the per-round cap, then the
runnable test and score formation (clock if runnable else +inf) — one VMEM
pass instead of four HLO loops over [N].

Layout mirrors the hines kernel's TPU transpose: neurons lie along the
128-wide lane dimension, the K in-edges along sublanes, so the min-reduce
is a full-width VPU column reduction over a [K, BN] tile.  The edge gather
(t_clock[pre] -> cand) happens outside the kernel as a single XLA gather —
the by-post edge layout makes the in-kernel work purely dense.

VMEM/block = (K + 3) * BN * 8B; K = 16, BN = 256 -> ~39 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN_DEFAULT = 256


def _horizon_kernel(cand_ref, t_clock_ref, hor_ref, score_ref, *,
                    t_end, horizon_cap, eps):
    cand = cand_ref[...]                       # [K, BN]
    t_c = t_clock_ref[...]                     # [1, BN]
    hor = jnp.minimum(jnp.min(cand, axis=0, keepdims=True), t_end)
    hor = jnp.minimum(hor, t_c + horizon_cap)
    runnable = t_c < hor - eps
    hor_ref[...] = hor
    score_ref[...] = jnp.where(runnable, t_c, jnp.inf)


def horizon_score_pallas(cand, t_clock, *, t_end: float, horizon_cap: float,
                         eps: float = 1e-12, block_n: int = BN_DEFAULT,
                         interpret: bool = True):
    """cand: [K, N] by-post candidates; t_clock: [N] -> (horizon[N], score[N]).

    N must be a multiple of block_n (the ops wrapper pads).
    """
    K, N = cand.shape
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    kernel = functools.partial(_horizon_kernel, t_end=t_end,
                               horizon_cap=horizon_cap, eps=eps)
    row = pl.BlockSpec((1, block_n), lambda i: (0, i))
    hor, score = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((K, block_n), lambda i: (0, i)), row],
        out_specs=(row, row),
        out_shape=(jax.ShapeDtypeStruct((1, N), cand.dtype),) * 2,
        interpret=interpret,
    )(cand, t_clock.reshape(1, N))
    return hor[0], score[0]


def _compact_kernel(mask_ref, vals_ref, idx_ref, val_ref, cnt_ref, *, cap, m):
    """One grid step compacts one mask row: cumsum rank -> one-hot place.

    The rank of event j among its row's survivors is ``cumsum(mask)[j]-1``
    (the event-wheel's free-slot search run in reverse: rank -> position
    instead of position -> rank); placement is a [cap, M] one-hot reduction —
    compares and sums only, so the whole compaction stays sort- and
    scatter-free inside the kernel.
    """
    msk = mask_ref[...].astype(jnp.int32)          # [1, M]
    vals = vals_ref[...]                           # [1, M]
    csum = jnp.cumsum(msk, axis=-1)
    pos = csum - msk                               # 0-based rank where mask=1
    total = csum[0, -1]
    slot = jax.lax.broadcasted_iota(jnp.int32, (cap, m), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (cap, m), 1)
    hit = jnp.logical_and(pos == slot, msk == 1)   # [cap, M]
    filled = slot[:, 0] < jnp.minimum(total, cap)
    idx = jnp.sum(jnp.where(hit, col, 0), axis=1)
    val = jnp.sum(jnp.where(hit, vals, 0.0), axis=1)
    idx_ref[...] = jnp.where(filled, idx, m)[None, :].astype(jnp.int32)
    val_ref[...] = jnp.where(filled, val, 0.0)[None, :]
    cnt_ref[...] = total[None, None].astype(jnp.int32)


def _compact_ids_kernel(mask_ref, ids_ref, cnt_ref, *, cap, bn):
    """Blocked 1-D generalisation of ``_compact_kernel`` emitting gather
    indices: the grid walks the mask in BN-wide blocks carrying the running
    set-lane count in ``cnt_ref``, so the one-hot placement tile stays
    [cap, BN] regardless of N (the row-at-once [cap, M] tile of the parcel
    packer would blow VMEM at frontier-mask lengths).  Ids are accumulated
    +1-biased so empty slots read 0 until the wrapper rewrites them to the
    sentinel."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        ids_ref[...] = jnp.zeros((1, cap), jnp.int32)
        cnt_ref[...] = jnp.zeros((1, 1), jnp.int32)

    base = cnt_ref[0, 0]
    msk = mask_ref[...]                            # [1, BN] i32
    csum = jnp.cumsum(msk, axis=-1).astype(jnp.int32)
    pos = base + csum - msk                        # global rank where mask=1
    slot = jax.lax.broadcasted_iota(jnp.int32, (cap, bn), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (cap, bn), 1)
    hit = jnp.logical_and(pos == slot, msk == 1)   # [cap, BN]
    gid1 = step * bn + col + 1                     # global index, +1-biased
    upd = jnp.sum(jnp.where(hit, gid1, 0), axis=1).astype(jnp.int32)
    ids_ref[...] += upd[None, :]
    cnt_ref[...] = (base + csum[0, -1]).astype(jnp.int32)[None, None]


def compact_ids_pallas(mask, *, cap: int, block_n: int = BN_DEFAULT,
                       interpret: bool = True):
    """Compact a bool[N] mask into the gather-id list of its set lanes.

    Returns (ids i32[cap] — indices of the first ``cap`` set lanes in
    index order, sentinel N for empty slots; count i32 — total set lanes,
    may exceed cap).  N must be a multiple of block_n (the ops wrapper
    pads with zeros).
    """
    (N,) = mask.shape
    assert N % block_n == 0, (N, block_n)
    kernel = functools.partial(_compact_ids_kernel, cap=cap, bn=block_n)
    acc, cnt = pl.pallas_call(
        kernel,
        grid=(N // block_n,),
        in_specs=[pl.BlockSpec((1, block_n), lambda i: (0, i))],
        out_specs=(pl.BlockSpec((1, cap), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((1, cap), jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)),
        interpret=interpret,
    )(mask.astype(jnp.int32).reshape(1, N))
    ids = jnp.where(acc[0] > 0, acc[0] - 1, N).astype(jnp.int32)
    return ids, cnt[0, 0]


def _compact_gather_kernel(mask_ref, tbl_ref, ids_ref, rows_ref, cnt_ref, *,
                           cap, bn, mo):
    """``_compact_ids_kernel`` generalised to also gather the rows of a
    static [N, MO] table for the set lanes — the compact fan-out's
    edge-index emitter: slot r of the output holds the table row of the
    r-th set lane.  Same blocked [cap, BN] one-hot placement, accumulated
    +1-biased across grid steps; the row gather is MO masked column
    reductions (compares and sums only — no gather, scatter or sort
    inside the kernel)."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        ids_ref[...] = jnp.zeros((1, cap), jnp.int32)
        rows_ref[...] = jnp.zeros((cap, mo), jnp.int32)
        cnt_ref[...] = jnp.zeros((1, 1), jnp.int32)

    base = cnt_ref[0, 0]
    msk = mask_ref[...]                            # [1, BN] i32
    tbl = tbl_ref[...]                             # [BN, MO] i32
    csum = jnp.cumsum(msk, axis=-1).astype(jnp.int32)
    pos = base + csum - msk                        # global rank where mask=1
    slot = jax.lax.broadcasted_iota(jnp.int32, (cap, bn), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (cap, bn), 1)
    hit = jnp.logical_and(pos == slot, msk == 1)   # [cap, BN]
    gid1 = step * bn + col + 1                     # global index, +1-biased
    ids_ref[...] += jnp.sum(jnp.where(hit, gid1, 0),
                            axis=1).astype(jnp.int32)[None, :]
    for m in range(mo):
        row1 = tbl[:, m][None, :] + 1              # [1, BN], +1-biased
        rows_ref[:, m] += jnp.sum(jnp.where(hit, row1, 0),
                                  axis=1).astype(jnp.int32)
    cnt_ref[...] = (base + csum[0, -1]).astype(jnp.int32)[None, None]


def compact_gather_pallas(mask, table, *, cap: int, fill: int,
                          block_n: int = BN_DEFAULT, interpret: bool = True):
    """Compact a bool[N] mask AND gather ``table``'s rows of its set lanes.

    table: i32[N, MO].  Returns (ids i32[cap] — indices of the first
    ``cap`` set lanes in index order, sentinel N for empty slots;
    rows i32[cap, MO] — table[ids], ``fill`` for empty slots; count i32 —
    total set lanes, may exceed cap).  N must be a multiple of block_n
    (the ops wrapper pads).
    """
    (N,) = mask.shape
    MO = table.shape[1]
    assert N % block_n == 0, (N, block_n)
    kernel = functools.partial(_compact_gather_kernel, cap=cap, bn=block_n,
                               mo=MO)
    acc, rows, cnt = pl.pallas_call(
        kernel,
        grid=(N // block_n,),
        in_specs=[pl.BlockSpec((1, block_n), lambda i: (0, i)),
                  pl.BlockSpec((block_n, MO), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((1, cap), lambda i: (0, 0)),
                   pl.BlockSpec((cap, MO), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((1, cap), jnp.int32),
                   jax.ShapeDtypeStruct((cap, MO), jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)),
        interpret=interpret,
    )(mask.astype(jnp.int32).reshape(1, N), table.astype(jnp.int32))
    ids = jnp.where(acc[0] > 0, acc[0] - 1, N).astype(jnp.int32)
    filled = acc[0] > 0
    rows = jnp.where(filled[:, None], rows - 1, fill).astype(jnp.int32)
    return ids, rows, cnt[0, 0]


def _segment_rank_kernel(key_ref, rank_ref, *, be):
    """Rank of each event within its key group, in event-index order: one
    [BE, BE] pairwise-equality pass per (j-block, i-block) grid cell — no
    per-round key table, no scatter, no sort.  Ranks accumulate across
    i-blocks (grid dim 1 iterates the strictly-earlier blocks first) and
    are clipped at ``max_rank`` by the wrapper."""
    jb, ib = pl.program_id(0), pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        rank_ref[...] = jnp.zeros((1, be), jnp.int32)

    @pl.when(ib <= jb)
    def _accum():
        kj = key_ref[0, pl.dslice(jb * be, be)]        # [BE] this block's keys
        ki = key_ref[0, pl.dslice(ib * be, be)]        # [BE] earlier block
        same = kj[:, None] == ki[None, :]              # [BE, BE]
        jj = jax.lax.broadcasted_iota(jnp.int32, (be, be), 0)
        ii = jax.lax.broadcasted_iota(jnp.int32, (be, be), 1)
        earlier = jnp.where(jb == ib, ii < jj, True)   # strict on diagonal
        rank_ref[...] += jnp.sum(jnp.logical_and(same, earlier),
                                 axis=1).astype(jnp.int32)[None, :]


def segment_rank_pallas(key, *, max_rank: int, block_e: int = 512,
                        interpret: bool = True):
    """Pairwise segment ranking for the wheel's generic insert: rank[j] =
    |{i < j : key[i] == key[j]}| clipped at ``max_rank`` — one VMEM pass
    over [BE, BE] tiles instead of ``segment_rank``'s ``max_rank`` rounds
    of scatter-min over an O(n_keys) table.  E is padded to block_e by the
    ops wrapper."""
    (E,) = key.shape
    assert E % block_e == 0, (E, block_e)
    nb = E // block_e
    kernel = functools.partial(_segment_rank_kernel, be=block_e)
    rank = pl.pallas_call(
        kernel,
        grid=(nb, nb),
        in_specs=[pl.BlockSpec((1, E), lambda j, i: (0, 0))],
        out_specs=pl.BlockSpec((1, block_e), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, E), jnp.int32),
        interpret=interpret,
    )(key.astype(jnp.int32).reshape(1, E))
    return jnp.minimum(rank[0], max_rank)


def compact_rows_pallas(mask, values, *, cap: int, interpret: bool = True):
    """Row-wise sort-free stream compaction (the spike-parcel packer).

    mask: [D, M] (bool/int — nonzero = keep); values: [D, M] f64.
    Returns (idx i32[D, cap] — column index of the r-th kept element, sentinel
    M for empty slots; vals f64[D, cap]; count i32[D] — total kept per row,
    which may exceed cap: the overflow is the caller's drop counter).
    """
    D, M = mask.shape
    row_in = pl.BlockSpec((1, M), lambda d: (d, 0))
    row_out = pl.BlockSpec((1, cap), lambda d: (d, 0))
    kernel = functools.partial(_compact_kernel, cap=cap, m=M)
    idx, vals, cnt = pl.pallas_call(
        kernel,
        grid=(D,),
        in_specs=[row_in, row_in],
        out_specs=(row_out, row_out, pl.BlockSpec((1, 1), lambda d: (d, 0))),
        out_shape=(jax.ShapeDtypeStruct((D, cap), jnp.int32),
                   jax.ShapeDtypeStruct((D, cap), values.dtype),
                   jax.ShapeDtypeStruct((D, 1), jnp.int32)),
        interpret=interpret,
    )(mask.astype(jnp.int32), values)
    return idx, vals, cnt[:, 0]

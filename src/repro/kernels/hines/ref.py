"""Pure-jnp oracles for the batched Hines solve/factor kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hines import hines_factor, hines_solve, hines_solve_factored


def hines_solve_ref(parent, g_axial, d, b):
    """d, b: [C, N] -> x: [C, N]; vmap of the O(C) reference solver."""
    sol = jax.vmap(lambda dd, bb: hines_solve(parent, g_axial, dd, bb),
                   in_axes=(1, 1), out_axes=1)
    return sol(d, b)


def hines_factor_ref(parent, g_axial, d):
    """d: [C, N] -> d_elim: [C, N]; vmap of the O(C) reference factor."""
    fac = jax.vmap(lambda dd: hines_factor(parent, g_axial, dd),
                   in_axes=1, out_axes=1)
    return fac(d)


def hines_solve_factored_ref(parent, g_axial, d_elim, b):
    """d_elim, b: [C, N] -> x: [C, N]; vmap of the factored solver."""
    sol = jax.vmap(lambda dd, bb: hines_solve_factored(parent, g_axial, dd, bb),
                   in_axes=(1, 1), out_axes=1)
    return sol(d_elim, b)


def dense_solve_ref(parent, g_axial, d, b):
    """Dense linear-algebra oracle (builds the full matrix per column)."""
    from repro.core.hines import dense_tree_matrix
    C, N = d.shape

    def one(dd, bb):
        # dd is the *assembled* diagonal here; rebuild the matrix directly
        mat = jnp.diag(dd)
        rows = jnp.arange(1, C)
        cols = parent[1:]
        mat = mat.at[rows, cols].add(-g_axial[1:])
        mat = mat.at[cols, rows].add(-g_axial[1:])
        return jnp.linalg.solve(mat, bb)

    return jax.vmap(one, in_axes=(1, 1), out_axes=1)(d, b)

"""Pallas TPU kernel: batched Hines tree-tridiagonal solve.

TPU adaptation (DESIGN.md §3): the GPU/CPU formulation walks one neuron's
tree serially.  On TPU we transpose the problem — the *batch* of neurons
lies along the 128-wide lane dimension and the compartment index along
sublanes, so every elimination/substitution step is a full-width VPU
operation over ``BN`` neurons at once.  All neurons in a block share one
topology (networks are built from morphology classes), so ``parent`` is a
scalar (SMEM) array driving dynamic sublane indexing.

Layout:  d, b, out x : [C, BN]  (compartments x neurons), g_axial: [C],
parent: int32[C].  VMEM footprint per block = 3 * C * BN * 4B (+2 vectors);
with C = 64, BN = 256 that is ~196 KiB — comfortably inside the ~16 MiB
v5e VMEM while keeping lanes full (BN multiple of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN_DEFAULT = 256          # neurons per block (lane multiples)


def _hines_kernel(parent_ref, gax_ref, d_ref, b_ref, x_ref, *, n_comp):
    C = n_comp
    idx_t = jnp.arange(1).dtype      # platform default int (int32 on TPU)

    def load_row(ref, i):
        return pl.load(ref, (pl.dslice(i, 1), slice(None)))      # [1, BN]

    def store_row(ref, i, val):
        pl.store(ref, (pl.dslice(i, 1), slice(None)), val)

    # copy inputs into the output buffers we mutate in place
    x_ref[...] = b_ref[...]
    dwork = d_ref[...]

    # --- backward (child -> parent) elimination --------------------------
    def elim(idx, dwork):
        i = (C - 1 - idx).astype(idx_t)                           # C-1 .. 1
        p = parent_ref[i].astype(idx_t)
        a_i = gax_ref[i]
        d_i = jax.lax.dynamic_slice_in_dim(dwork, i, 1, axis=0)
        b_i = load_row(x_ref, i)
        f = a_i / d_i
        d_p = jax.lax.dynamic_slice_in_dim(dwork, p, 1, axis=0)
        b_p = load_row(x_ref, p)
        dwork = jax.lax.dynamic_update_slice_in_dim(dwork, d_p - f * a_i, p, axis=0)
        store_row(x_ref, p, b_p + f * b_i)
        return dwork

    dwork = jax.lax.fori_loop(0, C - 1, elim, dwork)

    # --- forward (parent -> child) substitution ---------------------------
    root = load_row(x_ref, 0) / jax.lax.dynamic_slice_in_dim(dwork, 0, 1, axis=0)
    store_row(x_ref, 0, root)

    def subst(i, _):
        i = i.astype(idx_t)
        p = parent_ref[i].astype(idx_t)
        a_i = gax_ref[i]
        d_i = jax.lax.dynamic_slice_in_dim(dwork, i, 1, axis=0)
        v = (load_row(x_ref, i) + a_i * load_row(x_ref, p)) / d_i
        store_row(x_ref, i, v)
        return 0

    jax.lax.fori_loop(1, C, subst, 0)


def hines_solve_pallas(parent, g_axial, d, b, *, block_n: int = BN_DEFAULT,
                       interpret: bool = True):
    """Solve the batched tree system.  d, b: [C, N] -> x: [C, N].

    parent: int32[C] shared topology; g_axial: [C] (same dtype as d).
    N must be a multiple of block_n (wrappers pad).
    """
    C, N = d.shape
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    kernel = functools.partial(_hines_kernel, n_comp=C)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C,), lambda i: (0,)),                  # parent
            pl.BlockSpec((C,), lambda i: (0,)),                  # g_axial
            pl.BlockSpec((C, block_n), lambda i: (0, i)),        # d
            pl.BlockSpec((C, block_n), lambda i: (0, i)),        # b
        ],
        out_specs=pl.BlockSpec((C, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((C, N), d.dtype),
        interpret=interpret,
    )(parent, g_axial, d, b)


def _hines_factor_kernel(parent_ref, gax_ref, d_ref, de_ref, *, n_comp):
    """Backward elimination on the diagonal alone — the setup half of the
    split Newton solve.  The diagonal updates never read b, so the
    eliminated diagonal is an LU-style factor reusable across solves."""
    C = n_comp
    idx_t = jnp.arange(1).dtype

    de_ref[...] = d_ref[...]

    def elim(idx, _):
        i = (C - 1 - idx).astype(idx_t)                           # C-1 .. 1
        p = parent_ref[i].astype(idx_t)
        a_i = gax_ref[i]
        d_i = pl.load(de_ref, (pl.dslice(i, 1), slice(None)))
        d_p = pl.load(de_ref, (pl.dslice(p, 1), slice(None)))
        f = a_i / d_i
        pl.store(de_ref, (pl.dslice(p, 1), slice(None)), d_p - f * a_i)
        return 0

    jax.lax.fori_loop(0, C - 1, elim, 0)


def _hines_solve_factored_kernel(parent_ref, gax_ref, de_ref, b_ref, x_ref,
                                 *, n_comp):
    """The solve half: two O(C) sweeps against a stored eliminated
    diagonal.  Same FP op sequence on b as the fused kernel, so the
    composition factor-then-solve is bitwise-identical to one fused
    solve."""
    C = n_comp
    idx_t = jnp.arange(1).dtype

    def load_row(ref, i):
        return pl.load(ref, (pl.dslice(i, 1), slice(None)))      # [1, BN]

    def store_row(ref, i, val):
        pl.store(ref, (pl.dslice(i, 1), slice(None)), val)

    x_ref[...] = b_ref[...]

    # --- forward (child -> parent) elimination of b ----------------------
    def fwd(idx, _):
        i = (C - 1 - idx).astype(idx_t)                           # C-1 .. 1
        p = parent_ref[i].astype(idx_t)
        a_i = gax_ref[i]
        f = a_i / load_row(de_ref, i)
        store_row(x_ref, p, load_row(x_ref, p) + f * load_row(x_ref, i))
        return 0

    jax.lax.fori_loop(0, C - 1, fwd, 0)

    # --- backward (parent -> child) substitution --------------------------
    store_row(x_ref, 0, load_row(x_ref, 0) / load_row(de_ref, 0))

    def subst(i, _):
        i = i.astype(idx_t)
        p = parent_ref[i].astype(idx_t)
        a_i = gax_ref[i]
        v = (load_row(x_ref, i) + a_i * load_row(x_ref, p)) / load_row(de_ref, i)
        store_row(x_ref, i, v)
        return 0

    jax.lax.fori_loop(1, C, subst, 0)


def hines_factor_pallas(parent, g_axial, d, *, block_n: int = BN_DEFAULT,
                        interpret: bool = True):
    """Eliminate the batched assembled diagonal.  d: [C, N] -> d_elim: [C, N].

    parent: int32[C] shared topology; g_axial: [C] (same dtype as d).
    N must be a multiple of block_n (wrappers pad).
    """
    C, N = d.shape
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    kernel = functools.partial(_hines_factor_kernel, n_comp=C)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C,), lambda i: (0,)),                  # parent
            pl.BlockSpec((C,), lambda i: (0,)),                  # g_axial
            pl.BlockSpec((C, block_n), lambda i: (0, i)),        # d
        ],
        out_specs=pl.BlockSpec((C, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((C, N), d.dtype),
        interpret=interpret,
    )(parent, g_axial, d)


def hines_solve_factored_pallas(parent, g_axial, d_elim, b, *,
                                block_n: int = BN_DEFAULT,
                                interpret: bool = True):
    """Solve against a stored eliminated diagonal.  d_elim, b: [C, N] ->
    x: [C, N].  N must be a multiple of block_n (wrappers pad)."""
    C, N = d_elim.shape
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    kernel = functools.partial(_hines_solve_factored_kernel, n_comp=C)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C,), lambda i: (0,)),                  # parent
            pl.BlockSpec((C,), lambda i: (0,)),                  # g_axial
            pl.BlockSpec((C, block_n), lambda i: (0, i)),        # d_elim
            pl.BlockSpec((C, block_n), lambda i: (0, i)),        # b
        ],
        out_specs=pl.BlockSpec((C, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((C, N), d_elim.dtype),
        interpret=interpret,
    )(parent, g_axial, d_elim, b)

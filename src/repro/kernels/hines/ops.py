"""Public jit'd wrapper for the batched Hines solve Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret
from repro.kernels.hines.hines import BN_DEFAULT, hines_solve_pallas


@partial(jax.jit, static_argnames=("block_n",))
def hines_solve_batched(parent, g_axial, d, b, block_n: int = BN_DEFAULT):
    """Batched tree solve with automatic padding to the lane block size.

    parent: i32[C]; g_axial: [C]; d, b: [C, N] -> x: [C, N].
    Padding columns use the identity system (d=1, b=0) so they are inert.
    """
    C, N = d.shape
    n_pad = (-N) % block_n
    if n_pad:
        d = jnp.concatenate([d, jnp.ones((C, n_pad), d.dtype)], axis=1)
        b = jnp.concatenate([b, jnp.zeros((C, n_pad), b.dtype)], axis=1)
    x = hines_solve_pallas(parent, g_axial.astype(d.dtype), d, b,
                           block_n=block_n, interpret=use_interpret())
    return x[:, :N]

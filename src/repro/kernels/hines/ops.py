"""Public jit'd wrappers for the batched Hines Pallas kernels.

``hines_solve_batched`` is the original fused eliminate-and-solve pass;
``hines_factor_batched`` / ``hines_solve_factored_batched`` are its split
setup/solve halves (the reuse-don't-rebuild Newton: factor once per
setup, solve every iteration against the stored eliminated diagonal).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret
from repro.kernels.hines.hines import (BN_DEFAULT, hines_factor_pallas,
                                       hines_solve_factored_pallas,
                                       hines_solve_pallas)


@partial(jax.jit, static_argnames=("block_n",))
def hines_solve_batched(parent, g_axial, d, b, block_n: int = BN_DEFAULT):
    """Batched tree solve with automatic padding to the lane block size.

    parent: i32[C]; g_axial: [C]; d, b: [C, N] -> x: [C, N].
    Padding columns use the identity system (d=1, b=0) so they are inert.
    """
    C, N = d.shape
    n_pad = (-N) % block_n
    if n_pad:
        d = jnp.concatenate([d, jnp.ones((C, n_pad), d.dtype)], axis=1)
        b = jnp.concatenate([b, jnp.zeros((C, n_pad), b.dtype)], axis=1)
    x = hines_solve_pallas(parent, g_axial.astype(d.dtype), d, b,
                           block_n=block_n, interpret=use_interpret())
    return x[:, :N]


@partial(jax.jit, static_argnames=("block_n",))
def hines_factor_batched(parent, g_axial, d, block_n: int = BN_DEFAULT):
    """Batched diagonal elimination with automatic lane padding.

    parent: i32[C]; g_axial: [C]; d: [C, N] -> d_elim: [C, N].
    Padding columns use the identity diagonal (d=1) so they are inert.
    """
    C, N = d.shape
    n_pad = (-N) % block_n
    if n_pad:
        d = jnp.concatenate([d, jnp.ones((C, n_pad), d.dtype)], axis=1)
    de = hines_factor_pallas(parent, g_axial.astype(d.dtype), d,
                             block_n=block_n, interpret=use_interpret())
    return de[:, :N]


@partial(jax.jit, static_argnames=("block_n",))
def hines_solve_factored_batched(parent, g_axial, d_elim, b,
                                 block_n: int = BN_DEFAULT):
    """Batched factored solve with automatic lane padding.

    parent: i32[C]; g_axial: [C]; d_elim, b: [C, N] -> x: [C, N].
    Composes with ``hines_factor_batched`` to reproduce
    ``hines_solve_batched`` bitwise (identical FP op sequence on b).
    """
    C, N = d_elim.shape
    n_pad = (-N) % block_n
    if n_pad:
        d_elim = jnp.concatenate([d_elim, jnp.ones((C, n_pad), d_elim.dtype)],
                                 axis=1)
        b = jnp.concatenate([b, jnp.zeros((C, n_pad), b.dtype)], axis=1)
    x = hines_solve_factored_pallas(parent, g_axial.astype(d_elim.dtype),
                                    d_elim, b, block_n=block_n,
                                    interpret=use_interpret())
    return x[:, :N]

"""Pallas TPU kernel: causal GQA flash attention (online softmax).

The LM-zoo compute hot-spot.  Canonical TPU pattern: 3-axis grid
(batch*head, q_block, kv_block) with the (acc, m, l) running state in VMEM
scratch that persists across the innermost kv axis; the output tile is
finalised on the last kv block.  GQA is handled in the K/V index maps
(query head h reads kv head h // group).

Block sizes default to (128, 128): MXU-aligned, VMEM per block =
q(128xD) + k,v(128xD) + acc(128xD) + stats — ~0.4 MiB at D=128 fp32.

Supports q_len != kv_len (decode: q_len=1..few at the *end* of the causal
timeline, offset = kv_len - q_len).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, scale, causal, offset, kv_len, bq, bk, nk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                  # [bq, D]
    k = k_ref[0]                                  # [bk, D]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if causal:
        iq = pl.program_id(1)
        rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
        s = jnp.where(cols <= rows, s, NEG_INF)
    s = jnp.where(cols < kv_len, s, NEG_INF)      # mask padded kv columns

    m_prev = m_ref[...]                           # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                        # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)               # [bq, 1]
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           bq: int = 128, bk: int = 128,
                           offset: int | None = None, kv_len: int | None = None,
                           interpret: bool = True):
    """q: [B, H, Sq, D]; k, v: [B, Hkv, Skv, D] -> [B, H, Sq, D].

    offset/kv_len describe the *real* (pre-padding) causal geometry:
    offset = real_kv_len - real_q_len; kv_len = real kv length.
    """
    B, H, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert H % Hkv == 0
    group = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nk = Sq // bq, Skv // bk
    if kv_len is None:
        kv_len = Skv
    if offset is None:
        offset = kv_len - Sq
    scale = 1.0 / (D ** 0.5)
    qs = q.reshape(B * H, Sq, D)
    ks = k.reshape(B * Hkv, Skv, D)
    vs = v.reshape(B * Hkv, Skv, D)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               offset=offset, kv_len=kv_len, bq=bq, bk=bk, nk=nk)

    def kv_map(bh, iq, ik):
        # query head -> its GQA kv head within the same batch element
        return ((bh // H) * Hkv + (bh % H) // group, ik, 0)

    from jax.experimental.pallas import tpu as pltpu

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),     # acc
            pltpu.VMEM((bq, 1), jnp.float32),     # m
            pltpu.VMEM((bq, 1), jnp.float32),     # l
        ],
        interpret=interpret,
    )(qs, ks, vs)
    return out.reshape(B, H, Sq, D)

"""Pure-jnp oracle for flash attention (causal GQA, q_len <= kv_len)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q: [B, H, Sq, D]; k, v: [B, Hkv, Skv, D] -> [B, H, Sq, D]."""
    B, H, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = H // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (D ** 0.5)
    if causal:
        offset = Skv - Sq
        rows = jnp.arange(Sq)[:, None] + offset
        cols = jnp.arange(Skv)[None, :]
        s = jnp.where(cols <= rows, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)

"""Public jit'd wrapper for flash attention (pads seq dims to block size)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret
from repro.kernels.attention.flash import flash_attention_pallas


@partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128, bk: int = 128):
    """q: [B, H, Sq, D]; k, v: [B, Hkv, Skv, D] -> [B, H, Sq, D].

    Pads Sq/Skv up to block multiples; padded kv columns sit in the causal
    future (appended at the end) so they never contribute.
    """
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    bq_eff = min(bq, max(Sq, 1))
    bk_eff = min(bk, max(Skv, 1))
    pq = (-Sq) % bq_eff
    pk = (-Skv) % bk_eff
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = flash_attention_pallas(q, k, v, causal=causal, bq=bq_eff, bk=bk_eff,
                                 offset=Skv - Sq, kv_len=Skv,
                                 interpret=use_interpret())
    return out[:, :, :Sq]

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, and record the artifacts §Roofline reads.

Per cell:
  * full-depth compile  -> memory_analysis (fits-per-device proof),
                           trip-count-aware collective bytes (hlo_analysis),
                           raw cost_analysis (body-once, recorded as such)
  * depth La / Lb compiles -> exact per-layer FLOPs/bytes deltas, scaled to
    the full depth: total = c_a + (L - La)/(Lb - La) * (c_b - c_a)
    (XLA's HloCostAnalysis counts while bodies once — verified empirically;
    the delta method recovers the true totals; sub-layer *time* scans in the
    SSM families contribute <3% of layer FLOPs and are noted in DESIGN.md)

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh single
"""
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.configs.base import ArchConfig, ShapeConfig                 # noqa: E402
from repro.distributed import sharding as shd                          # noqa: E402
from repro.distributed.ctx import sharding_ctx                         # noqa: E402
from repro.launch.hlo_analysis import collective_bytes, collective_breakdown  # noqa: E402
from repro.launch.mesh import make_production_mesh                     # noqa: E402
from repro.models import lm                                            # noqa: E402
from repro.optim import adamw_init                                     # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------
def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def cost_dict(compiled) -> dict:
    """compiled.cost_analysis() across jax versions: old jax returns a list
    of per-computation dicts, new jax a single dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Stand-ins for every model input of this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sds((B, S), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = sds((B, S), jnp.int32)
        if cfg.family == "audio":
            batch["frames"] = sds((B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["patches"] = sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len cache
    token = sds((B, 1), jnp.int32)
    state = jax.eval_shape(lambda: lm.make_decode_state(cfg, B, S))
    return {"token": token, "state": state}


def params_specs_sds(cfg: ArchConfig):
    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    p = jax.tree_util.tree_leaves(params_specs_sds(cfg))
    n_total = sum(x.size for x in p)
    if cfg.n_experts:
        # active = total - (inactive experts' share)
        moe_per_layer = 3 * cfg.d_model * cfg.d_ff * cfg.n_experts
        active_moe = 3 * cfg.d_model * cfg.d_ff * cfg.top_k
        n_active = n_total - cfg.n_layers * (moe_per_layer - active_moe)
    else:
        n_active = n_total
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    return 2.0 * n_active * shape.global_batch          # decode: 1 token/seq


# ---------------------------------------------------------------------------
# builders: jitted fns + shardings per cell kind
# ---------------------------------------------------------------------------
def build(cfg: ArchConfig, shape: ShapeConfig, mesh):
    params = params_specs_sds(cfg)
    p_specs = shd.to_named(
        shd.fit_specs(shd.param_specs(cfg, params, mesh), params, mesh), mesh)

    if shape.kind == "train":
        opt = jax.eval_shape(adamw_init, params)
        o_specs = shd.to_named(
            shd.fit_specs(shd.opt_specs(cfg, opt, mesh), opt, mesh), mesh)
        batch = input_specs(cfg, shape)
        b_specs_raw = shd.batch_specs(cfg, shape, mesh)
        b_specs_raw = {k: b_specs_raw[k] for k in batch}
        b_specs = shd.to_named(shd.fit_specs(b_specs_raw, batch, mesh), mesh)

        def fn(p, o, b):
            return lm.train_step(cfg, p, o, b, 1e-4, remat=True)

        return fn, (params, opt, batch), (p_specs, o_specs, b_specs)

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        b_specs_raw = shd.batch_specs(cfg, shape, mesh)
        b_specs_raw = {k: b_specs_raw[k] for k in batch}
        b_specs = shd.to_named(shd.fit_specs(b_specs_raw, batch, mesh), mesh)

        def fn(p, b):
            return lm.prefill(cfg, p, b)

        return fn, (params, batch), (p_specs, b_specs)

    # decode
    spec = input_specs(cfg, shape)
    s_specs = shd.to_named(
        shd.fit_specs(shd.decode_state_specs(cfg, shape, mesh),
                      spec["state"], mesh), mesh)
    t_specs = shd.to_named(
        shd.fit_specs(shd.token_spec(cfg, shape, mesh), spec["token"], mesh),
        mesh)

    def fn(p, token, state):
        return lm.decode_step(cfg, p, token, state, jnp.int32(shape.seq_len - 1))

    return fn, (params, spec["token"], spec["state"]), (p_specs, t_specs, s_specs)


def lower_and_compile(cfg, shape, mesh):
    fn, args, in_shardings = build(cfg, shape, mesh)
    with sharding_ctx(mesh):
        lowered = jax.jit(fn, in_shardings=in_shardings).lower(*args)
        t0 = time.time()
        compiled = lowered.compile()
        dt = time.time() - t0
    return lowered, compiled, dt


def _mem_dict(m):
    return {
        "argument_bytes": m.argument_size_in_bytes,
        "output_bytes": m.output_size_in_bytes,
        "temp_bytes": m.temp_size_in_bytes,
        "alias_bytes": m.alias_size_in_bytes,
        "code_bytes": m.generated_code_size_in_bytes,
    }


def _depth_pair(cfg: ArchConfig):
    period = max(cfg.shared_attn_every, 1) if cfg.family == "hybrid" else 1
    return period, 2 * period


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             skip_delta: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind, "time": time.strftime("%Y-%m-%d %H:%M:%S")}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    try:
        lowered, compiled, compile_s = lower_and_compile(cfg, shape, mesh)
        mem = compiled.memory_analysis()
        cost = cost_dict(compiled)
        print(f"  {arch}/{shape_name}/{mesh_kind} memory_analysis:", mem, flush=True)
        print(f"  {arch}/{shape_name}/{mesh_kind} cost_analysis: "
              f"flops={cost.get('flops')} bytes={cost.get('bytes accessed')}",
              flush=True)
        txt = compiled.as_text()
        coll_total, _ = collective_bytes(txt)
        coll_flat = collective_breakdown(txt)
        rec.update(
            status="ok", n_chips=int(n_chips), compile_seconds=compile_s,
            memory=_mem_dict(mem),
            cost_raw={"flops": cost.get("flops", 0.0),
                      "bytes_accessed": cost.get("bytes accessed", 0.0)},
            collective_bytes_per_device=coll_total,
            collective_breakdown_flat=coll_flat,
            model_flops=model_flops(cfg, shape),
        )
        del lowered, compiled, txt

        if not skip_delta:
            rec.update(delta_pass(cfg, shape, mesh))
    except Exception as e:                                   # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def delta_pass(cfg: ArchConfig, shape: ShapeConfig, mesh) -> dict:
    """Depth-delta FLOPs/bytes with fully-unrolled structural scans (so the
    compiled HLO contains every layer/chunk body; cost_analysis is then
    exact for the shallow models, and the L-scaling is linear algebra)."""
    from repro.models import common as cm

    La, Lb = _depth_pair(cfg)
    costs = {}
    with cm.unroll_scans():
        for Lx in (La, Lb):
            cfg_x = dataclasses.replace(cfg, n_layers=Lx)
            _, comp_x, _ = lower_and_compile(cfg_x, shape, mesh)
            cx = cost_dict(comp_x)
            costs[Lx] = (cx.get("flops", 0.0), cx.get("bytes accessed", 0.0))
            del comp_x
    scale = (cfg.n_layers - La) / (Lb - La)
    flops = costs[La][0] + scale * (costs[Lb][0] - costs[La][0])
    bytes_ = costs[La][1] + scale * (costs[Lb][1] - costs[La][1])
    return {"hlo_flops_per_device": flops, "hlo_bytes_per_device": bytes_,
            "delta_depths": [La, Lb],
            "delta_raw": {str(k): v for k, v in costs.items()}}


def run_delta_only(arch: str, shape_name: str, mesh_kind: str, out_dir: str):
    """Merge a (re)computed delta pass into an existing artifact."""
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
    if not os.path.exists(path):
        return
    rec = json.load(open(path))
    if rec.get("status") != "ok":
        return
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        rec.update(delta_pass(cfg, shape, mesh))
        rec["delta_method"] = "unrolled"
    except Exception as e:                                   # noqa: BLE001
        rec["delta_error"] = f"{type(e).__name__}: {e}"
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"[delta] {arch} {shape_name} {mesh_kind} "
          f"flops={rec.get('hlo_flops_per_device'):.3e}", flush=True)


def run_paper_cell(mesh_kind: str, optimized: bool = False) -> dict:
    """The paper's own workload: one FAP scheduler round, 2^20 neurons,
    sharded over every mesh axis (DESIGN.md §3).  optimized=True uses the
    shard-local event insert + explicit notification all-gathers (§Perf)."""
    from repro.core.cell import CellModel
    from repro.core.morphology import branched_tree
    from repro.distributed.fap_spmd import PaperNeuroSpec, build_fap_round

    name = "paper-neuro-opt" if optimized else "paper-neuro"
    rec = {"arch": name, "shape": "sim_round", "mesh": mesh_kind,
           "kind": "simulation", "time": time.strftime("%Y-%m-%d %H:%M:%S")}
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        model = CellModel(branched_tree(depth=3, seg_per_branch=2))
        spec = PaperNeuroSpec()
        fn, args, in_sh = build_fap_round(model, spec, mesh,
                                          optimized=optimized)
        with sharding_ctx(mesh):
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            t0 = time.time()
            compiled = lowered.compile()
            compile_s = time.time() - t0
        mem = compiled.memory_analysis()
        cost = cost_dict(compiled)
        print(f"  {name}/{mesh_kind} memory_analysis:", mem, flush=True)
        txt = compiled.as_text()
        coll_total, _ = collective_bytes(txt)
        rec.update(
            status="ok", n_chips=int(mesh.devices.size),
            compile_seconds=compile_s, memory=_mem_dict(mem),
            cost_raw={"flops": cost.get("flops", 0.0),
                      "bytes_accessed": cost.get("bytes accessed", 0.0)},
            collective_bytes_per_device=coll_total,
            collective_breakdown_flat=collective_breakdown(txt),
            n_neurons=spec.n_neurons, k_in=spec.k_in, n_comp=spec.n_comp,
        )
    except Exception as e:                                   # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def save(rec: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--skip-delta", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--delta-only", action="store_true",
                    help="recompute only the unrolled depth-delta FLOPs/bytes "
                         "and merge into existing artifacts")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for mesh_kind in meshes:
        for arch in archs:
            if arch in ("paper-neuro", "paper-neuro-opt"):
                path = os.path.join(args.out,
                                    f"{arch}__sim_round__{mesh_kind}.json")
                if args.skip_existing and os.path.exists(path):
                    continue
                rec = run_paper_cell(mesh_kind, optimized=arch.endswith("-opt"))
                save(rec, args.out)
                print(f"[{rec['status']}] {arch} sim_round {mesh_kind} "
                      f"{rec.get('error', '')[:80]}", flush=True)
                continue
            for shape_name in shapes:
                if args.delta_only:
                    run_delta_only(arch, shape_name, mesh_kind, args.out)
                    continue
                path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_kind}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[skip existing] {arch} {shape_name} {mesh_kind}")
                            continue
                t0 = time.time()
                rec = run_cell(arch, shape_name, mesh_kind, args.out,
                               skip_delta=args.skip_delta)
                save(rec, args.out)
                status = rec["status"]
                extra = rec.get("reason", rec.get("error", ""))[:80]
                print(f"[{status}] {arch} {shape_name} {mesh_kind} "
                      f"({time.time()-t0:.0f}s) {extra}", flush=True)


if __name__ == "__main__":
    main()

"""Training launcher: ``python -m repro.launch.train --arch smollm-135m ...``

Production posture on real hardware; on this container it drives reduced (or
custom-scaled) configs on CPU.  Wires together: config registry, data
pipeline, AdamW + schedule, sharded checkpointing, RestartManager (resume,
NaN quarantine, straggler monitor) and optional failure injection.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import RestartManager
from repro.checkpoint.fault_tolerance import SimulatedFailure
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataPipeline, make_batch
from repro.models import lm
from repro.optim import adamw_init, wsd_schedule


def scale_config(cfg, d_model=None, n_layers=None, vocab=None):
    kw = {}
    if d_model:
        kw["d_model"] = d_model
        if cfg.n_heads:
            kw["head_dim"] = max(d_model // cfg.n_heads, 8)
    if n_layers:
        kw["n_layers"] = n_layers
    if vocab:
        kw["vocab"] = vocab
    return dataclasses.replace(cfg, **kw) if kw else cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = scale_config(cfg, args.d_model, args.n_layers, args.vocab)
    shape = ShapeConfig("custom", args.seq, args.batch, "train")
    pipe = DataPipeline(cfg, shape, seed=args.seed)

    mgr = RestartManager(args.ckpt_dir, save_every=args.save_every)

    def init_fn():
        params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
        return {"params": params, "opt": adamw_init(params)}

    state, extras, start = mgr.resume_or_init(init_fn)
    if extras.get("data"):
        pipe.load_state_dict(extras["data"])
    pipe.step = start
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M start_step={start}")

    @jax.jit
    def jstep(params, opt, batch, lr):
        return lm.train_step(cfg, params, opt, batch, lr)

    def step_fn(state, step):
        batch = pipe.next_batch()
        lr = wsd_schedule(jnp.asarray(step), args.lr, warmup=20,
                          total=args.steps)
        params, opt, metrics = jstep(state["params"], state["opt"], batch, lr)
        return {"params": params, "opt": opt}, metrics

    try:
        state, history = mgr.run(
            state, start, args.steps, step_fn,
            data_state_fn=lambda: {"data": pipe.state_dict()},
            inject_failure_at=(args.inject_failure_at
                               if args.inject_failure_at >= 0 else None))
    except SimulatedFailure as e:
        print(f"[ft] {e} — restart the launcher to resume from checkpoint")
        return 75
    final = history[-1]["loss"] if history else float("nan")
    first = history[0]["loss"] if history else float("nan")
    print(f"done: steps={len(history)} loss {first:.4f} -> {final:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

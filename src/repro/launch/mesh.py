"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.  Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
for the dry-run (dryrun.py sets this itself before importing jax).

  single-pod:  (16, 16)      axes (data, model)          = 256 chips (v5e pod)
  multi-pod:   (2, 16, 16)   axes (pod, data, model)     = 512 chips
"""
from __future__ import annotations

import math

import jax

try:                                   # jax >= 0.5: explicit Auto axis types
    from jax.sharding import AxisType
except ImportError:                    # older jax: meshes are Auto implicitly
    AxisType = None


def make_mesh_compat(shape, axes, devices=None):
    """jax.make_mesh across jax versions: pass axis_types=(Auto, ...) when
    the installed jax supports it, plain make_mesh otherwise."""
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes, devices=devices,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:              # make_mesh without axis_types kwarg
            pass
    return jax.make_mesh(shape, axes, devices=devices)


def _mk(shape, axes, devices):
    return make_mesh_compat(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — run "
            "with XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return _mk(shape, axes, devices[:need])


def make_local_mesh(n_model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    n = len(jax.devices())
    n_model = min(n_model, n)
    return _mk((n // n_model, n_model), ("data", "model"),
               jax.devices()[: (n // n_model) * n_model])


def elastic_mesh(n_devices: int, model_parallel: int = 16):
    """Elasticity: mesh factory as a pure function of the device count.
    Resize = remesh + checkpoint restore with resharding (DESIGN.md §6)."""
    devices = jax.devices()[:n_devices]
    mp = math.gcd(model_parallel, n_devices)
    return _mk((n_devices // mp, mp), ("data", "model"), devices)

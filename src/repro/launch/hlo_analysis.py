"""Post-SPMD HLO analysis: per-device collective bytes with while-loop
trip-count awareness.

``compiled.cost_analysis()`` visits a while body ONCE (verified empirically),
and collective ops do not appear in ``cost_analysis`` at all.  We therefore
parse ``compiled.as_text()``:

  1. split the module into named computations,
  2. sum result-shape bytes of every all-gather / all-reduce / reduce-scatter
     / all-to-all / collective-permute per computation,
  3. build the call graph (while body/condition, calls, fusions) and
     propagate multiplicity: a while's body multiplies by its trip count,
     extracted from the loop-bound constant in its condition computation
     (XLA emits ``compare(counter, constant(N))`` for lax.scan loops),
  4. total per-device collective bytes = sum over reachable computations.

The same machinery reports trip-count-corrected FLOPs for dot ops (used to
cross-check the L1/L2 compile-delta method in dryrun.py).

``collective_channel_bytes`` additionally attributes each collective to a
logical *channel*: the exchange layer (``distributed.exchange``) wraps its
two notification channels in ``jax.named_scope`` tags that survive into the
compiled HLO's ``metadata={op_name=...}``, so per-channel byte budgets
(clock notifications vs spike parcels) are measured from the lowered
module, not estimated.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->")
_CALLED_RE = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations)=\{?%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_computations(hlo_text: str) -> Dict[str, list]:
    """Split HLO text into {computation_name: [instruction lines]}."""
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and ("->" in line) and ("{" in line):
            m = _COMP_START.match(line.replace("ENTRY ", "").strip())
            name = None
            m2 = re.match(r"^(?:ENTRY\s+)?%?([^\s(]+)", line.strip())
            if m2:
                name = m2.group(1).lstrip("%")
            cur = name
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
        elif cur is not None:
            comps[cur].append(stripped)
    return comps


_COLL_RE = re.compile(
    r"=\s*([^=]*?)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?(?:\.\d+)?\(")


def _iter_collectives(lines):
    """(result_bytes, line) for every collective instruction, skipping ROOT
    tuples and the *-done half of start/done pairs (counted once)."""
    for ln in lines:
        m = _COLL_RE.search(ln)
        if not m or ln.lstrip().startswith("ROOT tuple"):
            continue
        if "-done" in ln.split("(")[0]:
            continue
        yield _shape_bytes(m.group(1)), ln


def _collective_bytes_of(lines) -> int:
    return sum(b for b, _ in _iter_collectives(lines))


def _while_info(lines) -> list:
    """[(body, condition)] for while instructions in a computation."""
    out = []
    for ln in lines:
        if " while(" in ln:
            body = re.search(r"body=%?([\w\.\-]+)", ln)
            cond = re.search(r"condition=%?([\w\.\-]+)", ln)
            if body and cond:
                out.append((body.group(1), cond.group(1)))
    return out


def _calls(lines) -> list:
    out = []
    for ln in lines:
        for m in _CALLED_RE.finditer(ln):
            out.append(m.group(1))
    return out


def _trip_count(cond_lines) -> int:
    """Loop bound from the largest integer constant in the condition."""
    best = 1
    for ln in cond_lines:
        for m in _CONST_RE.finditer(ln):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes(hlo_text: str) -> Tuple[int, dict]:
    """Trip-count-aware per-device collective bytes of a compiled module."""
    comps = parse_computations(hlo_text)
    entry_name = None
    for ln in hlo_text.splitlines():
        if ln.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([^\s(]+)", ln)
            if m:
                entry_name = m.group(1).rstrip("{").strip()
            break
    if entry_name not in comps:
        entry_name = "__entry__" if "__entry__" in comps else next(iter(comps))

    local = {name: _collective_bytes_of(lines) for name, lines in comps.items()}
    memo: Dict[str, int] = {}

    def total_of(name: str, depth=0) -> int:
        if name in memo or depth > 50 or name not in comps:
            return memo.get(name, 0)
        memo[name] = 0                           # cycle guard
        lines = comps[name]
        t = local.get(name, 0)
        whiles = _while_info(lines)
        while_children = set()
        for body, cond in whiles:
            trips = _trip_count(comps.get(cond, []))
            t += trips * total_of(body, depth + 1)
            while_children.add(body)
            while_children.add(cond)
        for child in _calls(lines):
            if child in while_children or child == name:
                continue
            t += total_of(child, depth + 1)
        memo[name] = t
        return t

    total = total_of(entry_name)
    detail = {k: v for k, v in local.items() if v}
    return total, detail


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')

# the exchange layer's channel tags (see distributed/exchange.py).  The
# ragged transport's per-class parcel scopes (exchange_parcel_c<cap>) match
# the exchange_parcel substring, so the default attribution reports the
# static sum over all class branches; for a per-class breakdown pass the
# class tags WITH their trailing scope delimiter ("exchange_parcel_c4/",
# via exchange.class_tag) — a bare "exchange_parcel_c1" is a string
# prefix of "exchange_parcel_c12" and would swallow its bytes.
CHANNEL_TAGS = ("exchange_notify", "exchange_parcel", "exchange_counts")


def collective_channel_bytes(hlo_text: str,
                             tags: Tuple[str, ...] = CHANNEL_TAGS) -> dict:
    """Per-channel collective bytes of a compiled module (flat, body-once).

    A collective is attributed to the first ``tag`` appearing in its
    ``op_name`` metadata (jax.named_scope propagates scope names there);
    untagged collectives land in ``"other"``.  Result-shape bytes follow
    the same convention as ``collective_bytes`` (tuple-shaped all-to-all
    results sum their components, i.e. the full received buffer).
    """
    out = {t: 0 for t in tags}
    out["other"] = 0
    for b, ln in _iter_collectives(hlo_text.splitlines()):
        op = _OPNAME_RE.search(ln)
        name = op.group(1) if op else ""
        for t in tags:
            if t in name:
                out[t] += b
                break
        else:
            out["other"] += b
    return out


def collective_breakdown(hlo_text: str) -> dict:
    """Per-opcode byte totals (flat, body-once — for inspection)."""
    out = defaultdict(int)
    for ln in hlo_text.splitlines():
        if "=" not in ln:
            continue
        rhs = ln.split("=", 1)[1]
        for c in COLLECTIVES:
            if re.search(rf"\b{c}(\.\d+)?\(", rhs):
                lhs_type = rhs.split(c)[0]
                out[c] += _shape_bytes(lhs_type)
                break
    return dict(out)

"""Serving launcher: batched prefill + decode loop with request queueing.

``python -m repro.launch.serve --arch smollm-135m --reduced --requests 16``

Continuous-batching-lite: requests arrive with different prompt lengths; the
server prefills them (left-padded into the KV cache), then decodes in
lockstep batches, retiring sequences as they hit EOS/max-new-tokens and
admitting queued requests into freed slots.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4, help="batch slots")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))

    # request queue: (id, prompt tokens)
    queue = [(i, rng.integers(0, cfg.vocab, rng.integers(4, 32)))
             for i in range(args.requests)]
    B, S = args.slots, args.cache_len

    @jax.jit
    def jdecode(params, token, state, t_pos):
        return lm.decode_step(cfg, params, token, state, t_pos)

    state = lm.make_decode_state(cfg, B, S)
    slot_free = [True] * B
    slot_req = [None] * B
    slot_left = [0] * B
    cur_tok = np.zeros((B, 1), np.int32)
    done, n_tokens = 0, 0
    t_pos = 0
    t0 = time.time()
    # NOTE: single shared t_pos (lockstep windows) — a deliberate
    # simplification of slot-local positions, fine for throughput measure.
    while done < args.requests or any(not f for f in slot_free):
        # admit
        for b in range(B):
            if slot_free[b] and queue:
                rid, prompt = queue.pop(0)
                # prefill by feeding prompt tokens through decode steps
                for tok in prompt[:-1]:
                    if t_pos >= S - args.max_new - 1:
                        break
                    logits, state = jdecode(
                        params,
                        jnp.asarray(np.full((B, 1), tok, np.int32)),
                        state, jnp.int32(t_pos))
                    t_pos += 1
                cur_tok[b, 0] = prompt[-1]
                slot_free[b] = False
                slot_req[b] = rid
                slot_left[b] = args.max_new
        if all(slot_free):
            break
        # decode one step for the whole batch
        logits, state = jdecode(params, jnp.asarray(cur_tok), state,
                                jnp.int32(t_pos))
        t_pos += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for b in range(B):
            if slot_free[b]:
                continue
            cur_tok[b, 0] = nxt[b]
            n_tokens += 1
            slot_left[b] -= 1
            if slot_left[b] <= 0 or t_pos >= S - 1:
                slot_free[b] = True
                done += 1
        if t_pos >= S - 2:
            # cache exhausted: reset window (toy rollover)
            state = lm.make_decode_state(cfg, B, S)
            t_pos = 0
    dt = time.time() - t0
    print(f"served {done} requests, {n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens/max(dt,1e-9):.1f} tok/s, slots={B})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving launchers: the LM continuous-batching loop and the
multi-tenant simulation service (``repro.serve``).

LM mode (default)::

    python -m repro.launch.serve --arch smollm-135m --reduced --requests 16

Continuous-batching-lite: requests arrive with different prompt lengths;
the server prefills them (left-padded into the KV cache), then decodes in
lockstep batches, retiring sequences as they hit EOS/max-new-tokens and
admitting queued requests into freed slots.  Prefill feeds the prompt
through the batched decode step but commits the state update to the
admitting slot ONLY (``merge_slot_state``) — the other slots' caches are
bitwise untouched, so one tenant's prompt can never leak into another's
attention window.

Simulation mode (``--sim``)::

    python -m repro.launch.serve --sim --tenants 8 --lanes 4 --t-end 6

Drives ``repro.serve.SimService`` — continuous admission over a vmapped
FAP round, per-tenant quarantine/retry, QoS classes and overload
shedding — and prints the detected-never-silent ``ServeResult``
accounting.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm


def merge_slot_state(new_state, old_state, slot: int, batch: int):
    """Keep slot ``slot``'s updates from ``new_state``; every other slot
    keeps ``old_state`` bitwise.

    Every decode-state family (dense KV, MoE, SSM conv/state, hybrid,
    audio cross-attn) lays its leaves out as [n_layers, B, ...] — batch
    at axis 1 — so a one-hot select over that axis masks the prefill
    write generically, whatever the architecture.
    """
    keep = jnp.zeros((batch,), bool).at[slot].set(True)
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(keep.reshape((1, batch) + (1,) * (n.ndim - 2)),
                               n, o),
        new_state, old_state)


def lm_main(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))

    # request queue: (id, prompt tokens)
    queue = [(i, rng.integers(0, cfg.vocab, rng.integers(4, 32)))
             for i in range(args.requests)]
    B, S = args.slots, args.cache_len

    @jax.jit
    def jdecode(params, token, state, t_pos):
        return lm.decode_step(cfg, params, token, state, t_pos)

    state = lm.make_decode_state(cfg, B, S)
    slot_free = [True] * B
    slot_req = [None] * B
    slot_left = [0] * B
    cur_tok = np.zeros((B, 1), np.int32)
    done, n_tokens = 0, 0
    t_pos = 0
    t0 = time.time()
    while done < args.requests or any(not f for f in slot_free):
        # admit
        for b in range(B):
            if slot_free[b] and queue:
                rid, prompt = queue.pop(0)
                # prefill by feeding prompt tokens through decode steps,
                # committing the cache write to slot b only — active
                # neighbours' KV windows stay bitwise untouched
                for tok in prompt[:-1]:
                    if t_pos >= S - args.max_new - 1:
                        break
                    logits, new_state = jdecode(
                        params,
                        jnp.asarray(np.full((B, 1), tok, np.int32)),
                        state, jnp.int32(t_pos))
                    state = merge_slot_state(new_state, state, b, B)
                    t_pos += 1
                cur_tok[b, 0] = prompt[-1]
                slot_free[b] = False
                slot_req[b] = rid
                slot_left[b] = args.max_new
        if all(slot_free):
            break
        # decode one step for the whole batch
        logits, state = jdecode(params, jnp.asarray(cur_tok), state,
                                jnp.int32(t_pos))
        t_pos += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for b in range(B):
            if slot_free[b]:
                continue
            cur_tok[b, 0] = nxt[b]
            n_tokens += 1
            slot_left[b] -= 1
            if slot_left[b] <= 0 or t_pos >= S - 1:
                slot_free[b] = True
                done += 1
        if t_pos >= S - 2:
            # cache exhausted: reset window (toy rollover)
            state = lm.make_decode_state(cfg, B, S)
            t_pos = 0
    dt = time.time() - t0
    print(f"served {done} requests, {n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens/max(dt,1e-9):.1f} tok/s, slots={B})")
    return 0


def sim_main(args):
    from repro.checkpoint import ExponentialBackoff, FaultPlan
    from repro.core import morphology, network
    from repro.core.cell import CellModel
    from repro.serve import SimService, TenantRequest

    model = CellModel(morphology.soma_only())
    net = network.make_network(args.n, k_in=4, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    fault = None
    if args.poison_tenant >= 0:
        fault = FaultPlan(poison_at_round=args.poison_round,
                          poison_tenant=args.poison_tenant, poison_lane=0)
    svc = SimService(model, net, t_end=args.t_end, lanes=args.lanes,
                     queue_cap=args.queue_cap,
                     backoff=ExponentialBackoff(max_retries=args.max_retries),
                     qos_caps={0: max(2, args.n // 4)}, fault=fault,
                     ckpt_dir=args.ckpt_dir or None,
                     checkpoint_every=args.checkpoint_every)
    for rid in range(args.tenants):
        svc.submit(TenantRequest(
            rid=rid, iinj=float(0.14 + 0.03 * rng.random()),
            qos=int(rng.integers(0, 2))))
    t0 = time.time()
    res = svc.run()
    dt = time.time() - t0
    print(f"served {res.submitted} tenants in {res.rounds} rounds "
          f"({dt:.2f}s): {res.completed} completed, {res.evicted} evicted, "
          f"{res.rejected} rejected ({res.shed} shed), "
          f"{res.retried} retries / {res.quarantines} quarantines")
    w = res.health["admission_wait_rounds"]
    print(f"admission wait: mean {w['mean']:.1f} / max {w['max']} rounds; "
          f"straggler: {res.health['straggler']['flagged']} flagged of "
          f"{res.health['straggler']['recorded']}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sim", action="store_true",
                    help="serve FAP simulations (repro.serve) instead of LM")
    # LM mode
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4, help="batch slots")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    # simulation mode
    ap.add_argument("--n", type=int, default=12, help="neurons per tenant")
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--t-end", type=float, default=6.0)
    ap.add_argument("--queue-cap", type=int, default=8)
    ap.add_argument("--max-retries", type=int, default=3)
    ap.add_argument("--poison-tenant", type=int, default=-1,
                    help="rid to poison (FaultPlan demo; -1 = off)")
    ap.add_argument("--poison-round", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    args = ap.parse_args(argv)
    return sim_main(args) if args.sim else lm_main(args)


if __name__ == "__main__":
    raise SystemExit(main())

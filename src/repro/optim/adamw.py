"""AdamW with global-norm clipping, warmup-stable-decay schedule and optional
gradient compression hooks (top-k / 8-bit stochastic rounding of the
cross-pod all-reduce payload — distributed-optimization knobs for DCN).

Pure-pytree implementation: optimizer state shards exactly like parameters
(FSDP), so memory per device is 2x params / n_devices.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def wsd_schedule(step, peak_lr: float, warmup: int = 100,
                 total: int = 10000, decay_frac: float = 0.1):
    """Warmup -> stable -> linear decay."""
    step = step.astype(jnp.float32)
    w = jnp.minimum(step / max(warmup, 1), 1.0)
    decay_start = total * (1.0 - decay_frac)
    d = jnp.clip((total - step) / jnp.maximum(total - decay_start, 1.0), 0.0, 1.0)
    return peak_lr * w * d


def adamw_update(grads, state: AdamWState, params, lr,
                 b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    mu = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    nu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_p = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, mu=mu, nu=nu)


# ---------------------------------------------------------------------------
# gradient compression (cross-pod DCN payload reduction)
# ---------------------------------------------------------------------------
def compress_stochastic_int8(g, key):
    """Stochastic-rounding int8 quantisation: returns (q, scale)."""
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    x = g / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.round(x + noise), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale

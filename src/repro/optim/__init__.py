from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,  # noqa: F401
                               clip_by_global_norm, wsd_schedule)

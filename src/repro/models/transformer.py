"""Llama-family decoder-only transformer with GQA (+ optional QKV bias),
RMSNorm, RoPE and SwiGLU — covers deepseek-7b, qwen2-72b, internlm2-20b,
smollm-135m and the internvl2-1b language backbone.

Layer parameters are stacked on a leading [L, ...] axis and the stack is
executed with ``lax.scan`` — one layer's HLO regardless of depth, which keeps
multi-pod dry-run compiles tractable for 80-layer models.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import ctx as dctx
from repro.models import attention as attn
from repro.models import common as cm


def init_layer_params(cfg: ArchConfig, key) -> dict:
    d, hd = cfg.d_model, cfg.hd
    H, Hkv, ff = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    ks = jax.random.split(key, 8)
    dt = cfg.pdtype()
    p = {
        "ln1": jnp.ones((d,), dt),
        "wq": cm.dense_init(ks[0], d, H * hd, dt),
        "wk": cm.dense_init(ks[1], d, Hkv * hd, dt),
        "wv": cm.dense_init(ks[2], d, Hkv * hd, dt),
        "wo": cm.dense_init(ks[3], H * hd, d, dt),
        "ln2": jnp.ones((d,), dt),
        "w_gate": cm.dense_init(ks[4], d, ff, dt),
        "w_up": cm.dense_init(ks[5], d, ff, dt),
        "w_down": cm.dense_init(ks[6], ff, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((Hkv * hd,), dt)
        p["bv"] = jnp.zeros((Hkv * hd,), dt)
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer_params(cfg, k))(layer_keys)
    params = {
        "emb": cm.dense_init(k_emb, cfg.vocab, cfg.d_model, cfg.pdtype(), scale=0.02),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), cfg.pdtype()),
    }
    if cfg.n_patches:
        params["patch_proj"] = cm.dense_init(k_out, cfg.d_model, cfg.d_model,
                                             cfg.pdtype())
    return params


def _qkv(cfg: ArchConfig, lp, x):
    cd = cfg.cdtype()
    q = cm.mm(x, lp["wq"], cd)
    k = cm.mm(x, lp["wk"], cd)
    v = cm.mm(x, lp["wv"], cd)
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(jnp.float32)
        k = k + lp["bk"].astype(jnp.float32)
        v = v + lp["bv"].astype(jnp.float32)
    B, S, _ = x.shape
    q = q.reshape(B, S, cfg.n_heads, cfg.hd)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.hd)
    return q, k, v


def _mlp(cfg: ArchConfig, lp, x):
    cd = cfg.cdtype()
    h = cm.swiglu(cm.mm(x, lp["w_gate"], cd), cm.mm(x, lp["w_up"], cd))
    return cm.mm(h, lp["w_down"], cd), jnp.zeros((), jnp.float32)


def layer_forward(cfg: ArchConfig, lp, x, cos, sin, attn_chunk=1024,
                  ffn_fn=None):
    """Full-sequence causal layer (train / prefill). x: [B, S, d] f32.
    Returns (x', (k, v), aux) with k/v for cache construction."""
    h = cm.rms_norm(x, lp["ln1"])
    q, k, v = _qkv(cfg, lp, h)
    q = cm.apply_rope(q, cos, sin)
    k = cm.apply_rope(k, cos, sin)
    o = attn.chunked_causal_attention(q, k, v, chunk=attn_chunk,
                                      compute_dtype=cfg.cdtype())
    B, S, _, _ = o.shape
    x = x + cm.mm(o.reshape(B, S, -1), lp["wo"], cfg.cdtype())
    h = cm.rms_norm(x, lp["ln2"])
    y, aux = (ffn_fn or _mlp)(cfg, lp, h)
    x = x + y
    return x, (k, v), aux


def layer_decode(cfg: ArchConfig, lp, x, kc, vc, t_pos, cos, sin,
                 ffn_fn=None):
    """One-token decode. x: [B, 1, d]; kc/vc: [B, S, Hkv, hd] caches."""
    h = cm.rms_norm(x, lp["ln1"])
    q, k, v = _qkv(cfg, lp, h)
    q = cm.apply_rope(q, cos, sin)
    k = cm.apply_rope(k, cos, sin)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), t_pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), t_pos, axis=1)
    o = attn.decode_attention(q, kc, vc, t_pos + 1, cfg.cdtype())
    B = x.shape[0]
    x = x + cm.mm(o.reshape(B, 1, -1), lp["wo"], cfg.cdtype())
    h = cm.rms_norm(x, lp["ln2"])
    y, _ = (ffn_fn or _mlp)(cfg, lp, h)
    x = x + y
    return x, kc, vc


def embed_tokens(cfg: ArchConfig, params, tokens):
    return params["emb"][tokens].astype(jnp.float32)


def forward(cfg: ArchConfig, params, tokens, patch_embeds=None,
            attn_chunk: int = 1024, return_cache: bool = False,
            ffn_fn=None, remat: bool = False):
    """Full-sequence forward.  tokens: [B, S] (plus optional VLM patch
    embeddings [B, P, d] prepended).  Returns (hidden [B, S_tot, d],
    caches | None, aux_loss)."""
    x = dctx.constrain(embed_tokens(cfg, params, tokens), "tokens3d")
    if cfg.n_patches and patch_embeds is not None:
        pe = cm.mm(patch_embeds.astype(jnp.float32), params["patch_proj"],
                   cfg.cdtype())
        x = jnp.concatenate([pe, x], axis=1)
    B, S, _ = x.shape
    pos = jnp.arange(S)
    cos, sin = cm.rope_tables(pos, cfg.hd, cfg.rope_theta)

    layer_fn = lambda lp, x: layer_forward(cfg, lp, x, cos, sin,
                                           attn_chunk, ffn_fn)
    if remat:
        layer_fn = jax.checkpoint(layer_fn)

    def body(carry, lp):
        x, aux = carry
        x, kv, a = layer_fn(lp, x)
        x = dctx.constrain(x, "residual")
        return (x, aux + a), kv if return_cache else None

    (x, aux), caches = cm.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = cm.rms_norm(x, params["ln_f"])
    return x, caches, aux


def decode_step(cfg: ArchConfig, params, token, cache, t_pos, ffn_fn=None):
    """token: [B, 1] i32; cache: dict(k=[L,B,S,Hkv,hd], v=...). One step."""
    x = embed_tokens(cfg, params, token)
    cos, sin = cm.rope_tables(jnp.full((1,), t_pos), cfg.hd, cfg.rope_theta)

    def body(x, layer):
        lp, kc, vc = layer
        x, kc, vc = layer_decode(cfg, lp, x, kc, vc, t_pos, cos, sin, ffn_fn)
        return x, (kc, vc)

    x, (kc, vc) = cm.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = cm.rms_norm(x, params["ln_f"])
    logits = cm.mm(x, params["emb"].T, cfg.cdtype())
    return logits, {"k": kc, "v": vc}


def make_cache(cfg: ArchConfig, batch, seq_len, dtype=None):
    dtype = dtype or cfg.cdtype()
    shape = (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

"""Shared building blocks for the LM zoo.

Dtype policy: parameters are stored in ``cfg.param_dtype`` (f32 for training
with FSDP-sharded optimizer state), matmuls run in ``cfg.compute_dtype``
(bf16) with f32 accumulation via ``preferred_element_type``.
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax
import jax.numpy as jnp

Params = Any  # nested dict of arrays

# ---------------------------------------------------------------------------
# scan with a global unroll switch.  XLA's HloCostAnalysis counts while-loop
# bodies ONCE; the dry-run's depth-delta FLOPs measurement therefore compiles
# shallow (L=1/L=2) models with every structural scan fully unrolled.
# ---------------------------------------------------------------------------
_UNROLL = False


@contextlib.contextmanager
def unroll_scans():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev


def scan(body, init, xs, length=None):
    """lax.scan honoring the dry-run unroll switch (structural scans only —
    time-recurrence scans stay rolled; their FLOPs share is <3%, DESIGN.md)."""
    if _UNROLL:
        n = length
        if n is None:
            n = jax.tree_util.tree_leaves(xs)[0].shape[0]
        return jax.lax.scan(body, init, xs, length=length, unroll=max(n, 1))
    return jax.lax.scan(body, init, xs, length=length)


def mm(x, w, compute_dtype=jnp.bfloat16):
    """Matmul with bf16 inputs, f32 accumulation."""
    return jax.lax.dot_general(
        x.astype(compute_dtype), w.astype(compute_dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else (d_in ** -0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rms_norm(x, gamma, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32))


def layer_norm(x, gamma, beta, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x32 - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def rope_tables(positions, head_dim, theta=1e4):
    """positions: i32[...]; returns (cos, sin) with trailing dim head_dim//2."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., S, H, D]; cos/sin: [..., S, D//2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :] if x.ndim == cos.ndim + 2 else cos
    s = sin[..., None, :] if x.ndim == sin.ndim + 2 else sin
    # broadcast cos/sin over the head axis: x is [B, S, H, D]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def swiglu(x_gate, x_up):
    return jax.nn.silu(x_gate) * x_up


def softmax_xent(logits, labels, vocab):
    """Mean token cross-entropy; logits f32 [N, V], labels i32 [N]."""
    logits = logits.astype(jnp.float32)
    lmax = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - lmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + lmax[..., 0]
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - gold).mean()


def chunked_lm_loss(x, emb, labels, n_chunks: int = 8,
                    compute_dtype=jnp.bfloat16):
    """Cross-entropy over tied-embedding logits without materialising the
    full [B, S, V] tensor: scan over sequence chunks.

    x: [B, S, d] final hidden states; emb: [V, d]; labels: [B, S].
    """
    B, S, d = x.shape
    V = emb.shape[0]
    while S % n_chunks:
        n_chunks -= 1
    xc = x.reshape(B, n_chunks, S // n_chunks, d).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, S // n_chunks).swapaxes(0, 1)

    # checkpointed: logits are recomputed in backward, never all live at once
    @jax.checkpoint
    def body(acc, xl):
        xi, li = xl
        logits = mm(xi, emb.T, compute_dtype)            # [B, s, V] f32
        return acc + softmax_xent(logits.reshape(-1, V), li.reshape(-1), V), None

    total, _ = scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / n_chunks


def chunked_time_scan(step, carry0, seq, chunk: int = 64, remat: bool = True):
    """Recurrence over time in remat'd chunks: backward keeps carries only at
    chunk boundaries (T/chunk of them) instead of every step.

    step(carry, x_t) -> (carry, y_t); seq: pytree with leading time axis T.
    """
    T = jax.tree_util.tree_leaves(seq)[0].shape[0]
    if T < 2 * chunk or T % chunk:
        return jax.lax.scan(step, carry0, seq)

    n = T // chunk
    seq_c = jax.tree_util.tree_map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), seq)

    def chunk_body(carry, xs):
        return jax.lax.scan(step, carry, xs)

    if remat:
        chunk_body = jax.checkpoint(chunk_body)
    carry, ys = jax.lax.scan(chunk_body, carry0, seq_c)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((T,) + a.shape[2:]), ys)
    return carry, ys

"""LM architecture zoo: dense GQA transformers, MoE, RWKV6, Mamba2 hybrid,
whisper enc-dec, VLM backbone — unified train/prefill/decode API in lm.py."""

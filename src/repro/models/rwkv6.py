"""RWKV6 "Finch" (arXiv:2404.05892): attention-free, data-dependent decay.

Per head (dk = dv = head size) the time-mix state S in R^{dk x dv} evolves

    S_t = diag(w_t) S_{t-1} + k_t v_t^T,      w_t = exp(-exp(wf(x_t)))
    y_t = (r_t)^T (diag(u) k_t v_t^T + S_{t-1})

with token-shift interpolation feeding r/k/v/w/g projections and an output
gate g (SiLU).  Channel-mix is the square-ReLU two-layer FFN of RWKV.

Training/prefill runs the recurrence with ``lax.scan`` over time (exact;
the chunked-parallel form is a recorded §Perf optimization); decode carries
(S, token-shift) state — O(1) per token, which is what makes the
``long_500k`` cell tractable for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import ctx as dctx
from repro.models import common as cm

HEAD_SIZE = 64


def _n_heads(cfg: ArchConfig) -> int:
    return cfg.d_model // HEAD_SIZE


def init_layer_params(cfg: ArchConfig, key) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    H = _n_heads(cfg)
    ks = jax.random.split(key, 12)
    dt = cfg.pdtype()
    di = cm.dense_init
    return {
        "ln1": jnp.ones((d,), dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt),
        "mu_w": jnp.full((d,), 0.5, dt),
        "mu_g": jnp.full((d,), 0.5, dt),
        "wr": di(ks[0], d, d, dt),
        "wk": di(ks[1], d, d, dt),
        "wv": di(ks[2], d, d, dt),
        "wg": di(ks[3], d, d, dt),
        "wo": di(ks[4], d, d, dt),
        # data-dependent decay: low-rank lora + bias (the Finch signature)
        "w_lora_a": di(ks[5], d, 64, dt),
        "w_lora_b": di(ks[6], 64, d, dt),
        "w_bias": jnp.full((d,), -4.0, dt),
        "bonus_u": (jax.random.normal(ks[7], (H, HEAD_SIZE), jnp.float32) * 0.1).astype(dt),
        "ln_x": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
        "cm_k": di(ks[8], d, ff, dt),
        "cm_v": di(ks[9], ff, d, dt),
        "cm_r": di(ks[10], d, d, dt),
        "mu_ck": jnp.full((d,), 0.5, dt),
        "mu_cr": jnp.full((d,), 0.5, dt),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    k_emb, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer_params(cfg, k))(layer_keys)
    return {
        "emb": cm.dense_init(k_emb, cfg.vocab, cfg.d_model, cfg.pdtype(), scale=0.02),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), cfg.pdtype()),
    }


def _shift_mix(x, x_prev, mu):
    """Token shift: lerp between current and previous token, channel-wise.
    x: [B, S, d]; x_prev: [B, d] (state before this block of tokens)."""
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    return x + (xs - x) * mu.astype(jnp.float32)


def time_mix(cfg: ArchConfig, lp, x, x_prev, S0):
    """x: [B, T, d]; x_prev: [B, d]; S0: [B, H, dk, dv].
    Returns (y, x_last, S_T)."""
    B, T, d = x.shape
    H = _n_heads(cfg)
    cd = cfg.cdtype()
    r = cm.mm(_shift_mix(x, x_prev, lp["mu_r"]), lp["wr"], cd)
    k = cm.mm(_shift_mix(x, x_prev, lp["mu_k"]), lp["wk"], cd)
    v = cm.mm(_shift_mix(x, x_prev, lp["mu_v"]), lp["wv"], cd)
    g = cm.mm(_shift_mix(x, x_prev, lp["mu_g"]), lp["wg"], cd)
    xw = _shift_mix(x, x_prev, lp["mu_w"])
    w_raw = lp["w_bias"].astype(jnp.float32) + cm.mm(
        jnp.tanh(cm.mm(xw, lp["w_lora_a"], cd)), lp["w_lora_b"], cd)
    w = jnp.exp(-jnp.exp(w_raw))                               # [B, T, d] in (0,1)

    hs = (B, T, H, HEAD_SIZE)
    r, k, v, w = (a.reshape(hs) for a in (r, k, v, w))
    u = lp["bonus_u"].astype(jnp.float32)

    def step(S, rkvw):
        rt, kt, vt, wt = rkvw                                  # [B, H, hs]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = S * wt[..., None] + kv
        return S, yt

    seq = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))  # [T, B, H, hs]
    S_T, y = cm.chunked_time_scan(step, S0, seq)
    y = y.transpose(1, 0, 2, 3).reshape(B, T, d)                # [B, T, d]
    y = cm.rms_norm(y, lp["ln_x"])
    y = y * jax.nn.silu(g)
    out = cm.mm(y, lp["wo"], cd)
    return out, x[:, -1], S_T


def channel_mix(cfg: ArchConfig, lp, x, x_prev):
    cd = cfg.cdtype()
    xk = _shift_mix(x, x_prev, lp["mu_ck"])
    xr = _shift_mix(x, x_prev, lp["mu_cr"])
    k = jnp.square(jax.nn.relu(cm.mm(xk, lp["cm_k"], cd)))
    kv = cm.mm(k, lp["cm_v"], cd)
    return jax.nn.sigmoid(cm.mm(xr, lp["cm_r"], cd)) * kv, x[:, -1]


def layer_forward(cfg: ArchConfig, lp, x, state):
    """state: dict(tm_x, cm_x: [B, d]; S: [B, H, dk, dv])."""
    h = cm.rms_norm(x, lp["ln1"])
    y, tm_x, S = time_mix(cfg, lp, h, state["tm_x"], state["S"])
    x = x + y
    h = cm.rms_norm(x, lp["ln2"])
    y, cm_x = channel_mix(cfg, lp, h, state["cm_x"])
    x = x + y
    return x, {"tm_x": tm_x, "cm_x": cm_x, "S": S}


def make_state(cfg: ArchConfig, batch, dtype=jnp.float32):
    H = _n_heads(cfg)
    return {
        "tm_x": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dtype),
        "cm_x": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dtype),
        "S": jnp.zeros((cfg.n_layers, batch, H, HEAD_SIZE, HEAD_SIZE), dtype),
    }


def forward(cfg: ArchConfig, params, tokens, state=None):
    """Full-sequence forward; returns (hidden, state')."""
    B, S = tokens.shape
    x = params["emb"][tokens].astype(jnp.float32)
    if state is None:
        state = make_state(cfg, B)

    def body(x, layer):
        lp, st = layer
        x, st = layer_forward(cfg, lp, x, st)
        x = dctx.constrain(x, "tokens3d")
        return x, st

    x, state = cm.scan(body, x, (params["layers"], state))
    x = cm.rms_norm(x, params["ln_f"])
    return x, state


def decode_step(cfg: ArchConfig, params, token, state, t_pos=None):
    """One-token decode: forward with T=1 (the recurrence IS the cache)."""
    del t_pos
    x, state = forward(cfg, params, token, state)
    logits = cm.mm(x, params["emb"].T, cfg.cdtype())
    return logits, state

"""Unified LM API over all families:

    init_params(cfg, key)                    -> params pytree
    forward_hidden(cfg, params, batch)       -> final hidden states
    loss_fn(cfg, params, batch)              -> scalar loss
    train_step(cfg, params, opt, batch, lr)  -> (params, opt, metrics)
    make_decode_state(cfg, B, S)             -> KV cache / recurrent state
    decode_step(cfg, params, token, state, t_pos) -> (logits, state)

``batch`` is a dict with "tokens"/"labels" (+ family-specific stub inputs:
"frames" for audio, "patches" for vlm).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import moe as moe_m
from repro.models import rwkv6 as rwkv_m
from repro.models import transformer as tfm
from repro.models import whisper as whisper_m
from repro.models import zamba2 as zamba_m
from repro.optim import adamw_init, adamw_update, clip_by_global_norm


def init_params(cfg: ArchConfig, key):
    if cfg.family in ("dense", "vlm"):
        return tfm.init_params(cfg, key)
    if cfg.family == "moe":
        return moe_m.init_params(cfg, key)
    if cfg.family == "ssm":
        return rwkv_m.init_params(cfg, key)
    if cfg.family == "hybrid":
        return zamba_m.init_params(cfg, key)
    if cfg.family == "audio":
        return whisper_m.init_params(cfg, key)
    raise ValueError(cfg.family)


def forward_hidden(cfg: ArchConfig, params, batch, attn_chunk=1024,
                   remat=False):
    """Returns (hidden [B, S, d] aligned with labels, aux_loss)."""
    tokens = batch["tokens"]
    if cfg.family == "dense":
        x, _, aux = tfm.forward(cfg, params, tokens, attn_chunk=attn_chunk,
                                remat=remat)
        return x, aux
    if cfg.family == "vlm":
        x, _, aux = tfm.forward(cfg, params, tokens,
                                patch_embeds=batch["patches"],
                                attn_chunk=attn_chunk, remat=remat)
        return x[:, cfg.n_patches:], aux          # loss on text positions
    if cfg.family == "moe":
        x, _, aux = tfm.forward(cfg, params, tokens, attn_chunk=attn_chunk,
                                ffn_fn=moe_m.moe_ffn_fn, remat=remat)
        return x, aux
    if cfg.family == "ssm":
        x, _ = rwkv_m.forward(cfg, params, tokens)
        return x, jnp.zeros((), jnp.float32)
    if cfg.family == "hybrid":
        x, _ = zamba_m.forward(cfg, params, tokens, attn_chunk=attn_chunk)
        return x, jnp.zeros((), jnp.float32)
    if cfg.family == "audio":
        x = whisper_m.forward(cfg, params, tokens, batch["frames"],
                              attn_chunk=attn_chunk)
        return x, jnp.zeros((), jnp.float32)
    raise ValueError(cfg.family)


def loss_fn(cfg: ArchConfig, params, batch, aux_weight=0.01, attn_chunk=1024,
            remat=False):
    x, aux = forward_hidden(cfg, params, batch, attn_chunk, remat=remat)
    lm = cm.chunked_lm_loss(x, params["emb"], batch["labels"],
                            compute_dtype=cfg.cdtype())
    return lm + aux_weight * aux, (lm, aux)


def train_step(cfg: ArchConfig, params, opt_state, batch, lr,
               max_grad_norm: float = 1.0, attn_chunk: int = 1024,
               remat: bool = False):
    """One AdamW training step (grads via jax.grad, global-norm clipped)."""
    (loss, (lm, aux)), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch, attn_chunk=attn_chunk, remat=remat),
        has_aux=True)(params)
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    params, opt_state = adamw_update(grads, opt_state, params, lr)
    metrics = {"loss": loss, "lm_loss": lm, "aux_loss": aux, "grad_norm": gnorm}
    return params, opt_state, metrics


def init_train_state(cfg: ArchConfig, key):
    params = init_params(cfg, key)
    return params, adamw_init(params)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def make_decode_state(cfg: ArchConfig, batch: int, seq_len: int):
    if cfg.family in ("dense", "vlm", "moe"):
        return tfm.make_cache(cfg, batch, seq_len)
    if cfg.family == "ssm":
        return rwkv_m.make_state(cfg, batch)
    if cfg.family == "hybrid":
        return zamba_m.make_state(cfg, batch, seq_len)
    if cfg.family == "audio":
        return whisper_m.make_cache(cfg, batch, seq_len)
    raise ValueError(cfg.family)


def decode_step(cfg: ArchConfig, params, token, state, t_pos):
    """token: [B, 1] i32.  Returns (logits [B, 1, V], state')."""
    if cfg.family in ("dense", "vlm"):
        return tfm.decode_step(cfg, params, token, state, t_pos)
    if cfg.family == "moe":
        return tfm.decode_step(cfg, params, token, state, t_pos,
                               ffn_fn=moe_m.moe_ffn_fn)
    if cfg.family == "ssm":
        return rwkv_m.decode_step(cfg, params, token, state, t_pos)
    if cfg.family == "hybrid":
        return zamba_m.decode_step(cfg, params, token, state, t_pos)
    if cfg.family == "audio":
        return whisper_m.decode_step(cfg, params, token, state, t_pos)
    raise ValueError(cfg.family)


def prefill(cfg: ArchConfig, params, batch, attn_chunk=1024):
    """Prefill forward (logits for the last position only)."""
    x, _ = forward_hidden(cfg, params, batch, attn_chunk)
    logits = cm.mm(x[:, -1:], params["emb"].T, cfg.cdtype())
    return logits

"""Mixture-of-Experts FFN with top-k routing and capacity-based, sort-free
dispatch (argsort-based grouping; no [T, E, C] one-hot tensor).

Covers arctic-480b (128e top-2 + dense residual FFN) and qwen3-moe-30b-a3b
(128e top-8).  Expert weights are stacked [E, ...] and sharded over the
``model`` mesh axis (expert parallelism); the gather/scatter between
token-sharded and expert-sharded layouts is the MoE all-to-all, inserted by
GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import ctx as dctx
from repro.models import common as cm


def init_moe_params(cfg: ArchConfig, key) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype()
    scale = d ** -0.5
    return {
        "router": cm.dense_init(ks[0], d, E, dt, scale=0.02),
        "w_gate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * scale).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) * scale).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, ff, d), jnp.float32) * (ff ** -0.5)).astype(dt),
    }


def moe_ffn(cfg: ArchConfig, mp, x, n_groups: int = 0):
    """x: [B, S, d] f32 -> [B, S, d] f32 (+ aux load-balance loss).

    GShard-style *grouped* dispatch: tokens are split into G groups (aligned
    with the data-parallel shards), routing/dispatch happens per group with
    per-group capacity, and the dispatch buffer is [G, E, C_g, d] — sharded
    G over data and E over model, so the only cross-device movement is the
    canonical MoE all-to-all (group-sharded -> expert-sharded).  A single
    global-capacity buffer (the naive form) materialises [E, 1.25*T*k/E, d]
    and cannot fit at train_4k scale — §Perf baseline->opt comparison.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = n_groups or _DEFAULT_GROUPS[0]
    G = min(G, max(1, T // 64))        # keep per-group capacity meaningful
    while T % G:
        G -= 1
    Tg = T // G
    xt = x.reshape(G, Tg, d)
    logits = cm.mm(xt, mp["router"], cfg.cdtype())           # [G, Tg, E] f32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # [G, Tg, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style, global)
    me = probs.mean(axis=(0, 1))                              # [E]
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (T * k)
    aux = (E * jnp.sum(me * ce)).astype(jnp.float32)

    cap = int(cfg.capacity_factor * Tg * k / E) + 1

    def dispatch_group(xg, eg, gg):
        """xg: [Tg, d]; eg/gg: [Tg, k] -> (buf [E, C, d], combine meta)."""
        flat_e = eg.reshape(-1)                               # [Tg*k]
        flat_g = gg.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(Tg), k)
        order = jnp.argsort(flat_e, stable=True)
        e_s, g_s, t_s = flat_e[order], flat_g[order], flat_t[order]
        seg_start = jnp.searchsorted(e_s, e_s, side="left")
        rank = jnp.arange(Tg * k) - seg_start
        keep = rank < cap
        row = jnp.where(keep, e_s, E)
        slot = jnp.clip(rank, 0, cap - 1)
        buf = jnp.zeros((E, cap, d), xg.dtype).at[row, slot].set(
            xg[t_s], mode="drop")
        return buf, (row, slot, t_s, g_s, keep)

    buf, meta = jax.vmap(dispatch_group)(xt, expert_idx, gate_vals)
    buf = dctx.constrain(buf, "moe_buf")       # [G, E, C, d]: the all-to-all
    cd = cfg.cdtype()
    h = cm.swiglu(
        jnp.einsum("gecd,edf->gecf", buf.astype(cd), mp["w_gate"].astype(cd),
                   preferred_element_type=jnp.float32),
        jnp.einsum("gecd,edf->gecf", buf.astype(cd), mp["w_up"].astype(cd),
                   preferred_element_type=jnp.float32))
    y = jnp.einsum("gecf,efd->gecd", h.astype(cd), mp["w_down"].astype(cd),
                   preferred_element_type=jnp.float32)        # [G, E, C, d]
    y = dctx.constrain(y, "moe_buf")           # all-to-all back

    def combine_group(yg, m):
        row, slot, t_s, g_s, keep = m
        contrib = yg[row.clip(0, E - 1), slot] * g_s[:, None]
        contrib = jnp.where(keep[:, None], contrib, 0.0)
        return jnp.zeros((Tg, d), jnp.float32).at[t_s].add(contrib)

    out = jax.vmap(combine_group)(y, meta)
    return out.reshape(B, S, d), aux


# default group count ~= data-parallel degree of the production mesh;
# mutable so the dry-run can align it with the active mesh.
_DEFAULT_GROUPS = [32]


# ---------------------------------------------------------------------------
# integration with the transformer stack (pluggable FFN)
# ---------------------------------------------------------------------------
def init_layer_params(cfg: ArchConfig, key) -> dict:
    """Attention params + MoE params (+ dense residual FFN for arctic)."""
    from repro.models import transformer as tfm

    k_attn, k_moe = jax.random.split(key)
    p = tfm.init_layer_params(cfg, k_attn)
    if not cfg.dense_residual:
        # replace the dense FFN with MoE-only params
        for name in ("w_gate", "w_up", "w_down"):
            del p[name]
    p["moe"] = init_moe_params(cfg, k_moe)
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    from repro.models import transformer as tfm

    k_emb, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer_params(cfg, k))(layer_keys)
    return {
        "emb": cm.dense_init(k_emb, cfg.vocab, cfg.d_model, cfg.pdtype(), scale=0.02),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), cfg.pdtype()),
    }


def moe_ffn_fn(cfg: ArchConfig, lp, h):
    """FFN hook for transformer.layer_forward: MoE (+ dense residual)."""
    y, aux = moe_ffn(cfg, lp["moe"], h)
    if cfg.dense_residual:
        cd = cfg.cdtype()
        dense = cm.mm(
            cm.swiglu(cm.mm(h, lp["w_gate"], cd), cm.mm(h, lp["w_up"], cd)),
            lp["w_down"], cd)
        y = y + dense
    return y, aux

"""Zamba2-style hybrid (arXiv:2411.15242): a backbone of Mamba2 blocks with a
single *shared* attention+MLP block applied every ``shared_attn_every``
layers (parameter reuse is the Zamba signature — one transformer block's
weights serve all applications).

Mamba2 (arXiv:2405.21060, SSD) block here: in-projection to (z, x, B, C, dt),
depthwise causal conv on x, selective state update with scalar-per-head decay

    h_t = exp(-exp(A_log) * dt_t) * h_{t-1} + dt_t * (B_t x_t^T)
    y_t = C_t^T h_t + D * x_t

with state h in R^{n_state x d_head} per head.  Recurrence via lax.scan
(exact; chunked form is a recorded §Perf optimization).  Decode carries
(conv window, h) — O(1) per token, so ``long_500k`` is tractable; only the
shared attention block keeps a KV cache (seq-sharded in the dry-run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import ctx as dctx
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import transformer as tfm


def _dims(cfg: ArchConfig):
    d_inner = 2 * cfg.d_model
    n_heads = d_inner // 64            # mamba2 head size 64
    return d_inner, n_heads


def init_mamba_params(cfg: ArchConfig, key) -> dict:
    d = cfg.d_model
    d_inner, H = _dims(cfg)
    n = cfg.ssm_state
    ks = jax.random.split(key, 6)
    dt = cfg.pdtype()
    di = cm.dense_init
    return {
        "ln": jnp.ones((d,), dt),
        # fused in-projection: z, x, B, C, dt
        "w_in": di(ks[0], d, 2 * d_inner + 2 * n + H, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_inner), jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((d_inner,), dt),
        "A_log": jnp.zeros((H,), dt),
        "D": jnp.ones((H,), dt),
        "dt_bias": jnp.zeros((H,), dt),
        "w_out": di(ks[2], d_inner, d, dt),
        "ln_y": jnp.ones((d_inner,), dt),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    k_emb, k_layers, k_shared = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_mamba_params(cfg, k))(layer_keys)
    return {
        "emb": cm.dense_init(k_emb, cfg.vocab, cfg.d_model, cfg.pdtype(), scale=0.02),
        "layers": layers,
        "shared": tfm.init_layer_params(cfg, k_shared),   # ONE shared block
        "ln_f": jnp.ones((cfg.d_model,), cfg.pdtype()),
    }


def _split_in(cfg, proj):
    d_inner, H = _dims(cfg)
    n = cfg.ssm_state
    z = proj[..., :d_inner]
    xs = proj[..., d_inner:2 * d_inner]
    Bm = proj[..., 2 * d_inner:2 * d_inner + n]
    Cm = proj[..., 2 * d_inner + n:2 * d_inner + 2 * n]
    dt_raw = proj[..., 2 * d_inner + 2 * n:]
    return z, xs, Bm, Cm, dt_raw


def mamba_forward(cfg: ArchConfig, lp, x, conv_state, h0):
    """x: [B, T, d]; conv_state: [B, K-1, d_inner]; h0: [B, H, n, hd].
    Returns (y, conv_state', h_T)."""
    B, T, d = x.shape
    d_inner, H = _dims(cfg)
    n, K = cfg.ssm_state, cfg.ssm_conv
    hd = d_inner // H
    cd = cfg.cdtype()
    hn = cm.rms_norm(x, lp["ln"])
    proj = cm.mm(hn, lp["w_in"], cd)
    z, xs, Bm, Cm, dt_raw = _split_in(cfg, proj)

    # depthwise causal conv over time (window K) with carried state
    xc = jnp.concatenate([conv_state, xs], axis=1)            # [B, K-1+T, di]
    w = lp["conv_w"].astype(jnp.float32)
    xconv = sum(xc[:, i:i + T] * w[i][None, None] for i in range(K))
    xconv = jax.nn.silu(xconv + lp["conv_b"].astype(jnp.float32))
    conv_state_new = xc[:, -(K - 1):] if K > 1 else conv_state

    dt_t = jax.nn.softplus(dt_raw + lp["dt_bias"].astype(jnp.float32))  # [B,T,H]
    a = jnp.exp(-jnp.exp(lp["A_log"].astype(jnp.float32))[None, None] * dt_t)
    xh = xconv.reshape(B, T, H, hd)

    def step(h, inp):
        xt, Bt, Ct, at, dtt = inp
        # h: [B, H, n, hd]
        upd = jnp.einsum("bn,bhp->bhnp", Bt, xt * dtt[..., None])
        h = h * at[..., None, None] + upd
        yt = jnp.einsum("bn,bhnp->bhp", Ct, h)
        return h, yt

    seq = (xh.transpose(1, 0, 2, 3), Bm.transpose(1, 0, 2),
           Cm.transpose(1, 0, 2), a.transpose(1, 0, 2), dt_t.transpose(1, 0, 2))
    hT, y = cm.chunked_time_scan(step, h0, seq)
    y = y.transpose(1, 0, 2, 3).reshape(B, T, d_inner)
    y = y + xconv * lp["D"].astype(jnp.float32).repeat(hd)[None, None]
    y = cm.rms_norm(y, lp["ln_y"]) * jax.nn.silu(z)
    out = cm.mm(y, lp["w_out"], cd)
    return x + out, conv_state_new, hT


def make_state(cfg: ArchConfig, batch, seq_len, dtype=jnp.float32,
               cache_dtype=None):
    """Recurrent state for all layers + KV cache for the shared attn block."""
    d_inner, H = _dims(cfg)
    L = cfg.n_layers
    n_apps = _n_shared_apps(cfg)
    cache_dtype = cache_dtype or cfg.cdtype()
    return {
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, d_inner), dtype),
        "h": jnp.zeros((L, batch, H, cfg.ssm_state, d_inner // H), dtype),
        "k": jnp.zeros((n_apps, batch, seq_len, cfg.n_kv_heads, cfg.hd), cache_dtype),
        "v": jnp.zeros((n_apps, batch, seq_len, cfg.n_kv_heads, cfg.hd), cache_dtype),
    }


def _n_shared_apps(cfg: ArchConfig) -> int:
    e = max(cfg.shared_attn_every, 1)
    return max(1, cfg.n_layers // e)


def forward(cfg: ArchConfig, params, tokens, state=None, attn_chunk=1024):
    """Full-sequence forward. The shared block is applied after every
    ``shared_attn_every``-th mamba layer (same weights each application).
    The layer stack runs as ``every``-sized scan segments with the shared
    block between segments, so FLOPs match the architecture exactly."""
    B, T = tokens.shape
    x = params["emb"][tokens].astype(jnp.float32)
    if state is None:
        state = make_state(cfg, B, T)
    pos = jnp.arange(T)
    cos, sin = cm.rope_tables(pos, cfg.hd, cfg.rope_theta)
    every = max(cfg.shared_attn_every, 1)
    n_apps = _n_shared_apps(cfg)
    L = cfg.n_layers

    def body(x, layer):
        lp, conv0, h0 = layer
        x, conv1, h1 = mamba_forward(cfg, lp, x, conv0, h0)
        x = dctx.constrain(x, "tokens3d")
        return x, (conv1, h1)

    convs, hs = [], []
    for app in range(n_apps + 1):
        lo = app * every
        hi = min((app + 1) * every, L)
        if lo >= L:
            break
        seg = jax.tree_util.tree_map(lambda a: a[lo:hi], params["layers"])
        x, (conv1, h1) = cm.scan(
            body, x, (seg, state["conv"][lo:hi], state["h"][lo:hi]))
        convs.append(conv1)
        hs.append(h1)
        if hi == lo + every and app < n_apps:
            x, _, _ = tfm.layer_forward(cfg, params["shared"], x, cos, sin,
                                        attn_chunk)
    conv = jnp.concatenate(convs, axis=0)
    h = jnp.concatenate(hs, axis=0)
    x = cm.rms_norm(x, params["ln_f"])
    state = dict(state, conv=conv, h=h)
    return x, state


def decode_step(cfg: ArchConfig, params, token, state, t_pos):
    """One-token decode: mamba recurrences + shared-attn KV caches."""
    B = token.shape[0]
    x = params["emb"][token].astype(jnp.float32)
    cos, sin = cm.rope_tables(jnp.full((1,), t_pos), cfg.hd, cfg.rope_theta)
    every = max(cfg.shared_attn_every, 1)
    n_apps = _n_shared_apps(cfg)
    L = cfg.n_layers

    # scan over mamba layers (recurrent state only)
    def body(carry, layer):
        x, i = carry
        lp, conv0, h0 = layer
        x, conv1, h1 = mamba_forward(cfg, lp, x, conv0, h0)
        return (x, i + 1), (conv1, h1)

    # we interleave shared-attn applications by running the mamba scan in
    # ``every``-sized segments; the shared block mutates its own app cache.
    xs = x
    convs, hs = [], []
    kc_all, vc_all = state["k"], state["v"]
    for app in range(n_apps + 1):
        lo = app * every
        hi = min((app + 1) * every, L)
        if lo >= L:
            break
        seg = jax.tree_util.tree_map(lambda a: a[lo:hi], params["layers"])
        (xs, _), (conv1, h1) = cm.scan(
            body, (xs, jnp.zeros((), jnp.int32)),
            (seg, state["conv"][lo:hi], state["h"][lo:hi]))
        convs.append(conv1)
        hs.append(h1)
        if hi == lo + every and app < n_apps:
            kc, vc = kc_all[app], vc_all[app]
            xs, kc, vc = tfm.layer_decode(cfg, params["shared"], xs, kc, vc,
                                          t_pos, cos, sin)
            kc_all = kc_all.at[app].set(kc)
            vc_all = vc_all.at[app].set(vc)
    conv = jnp.concatenate(convs, axis=0)
    h = jnp.concatenate(hs, axis=0)
    xs = cm.rms_norm(xs, params["ln_f"])
    logits = cm.mm(xs, params["emb"].T, cfg.cdtype())
    return logits, {"conv": conv, "h": h, "k": kc_all, "v": vc_all}

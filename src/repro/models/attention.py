"""GQA attention: chunked-causal for train/prefill (bounded score memory),
direct for decode.  The Pallas flash kernel (repro.kernels.attention) is the
TPU production path for train/prefill; the jnp path below is what the
multi-device dry-run lowers (XLA fuses it; the chunking bounds live memory
the same way flash blocking does).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def repeat_kv(k, group):
    """[B, S, Hkv, D] -> [B, S, Hkv*group, D]."""
    if group == 1:
        return k
    return jnp.repeat(k, group, axis=2)


def chunked_causal_attention(q, k, v, chunk: int = 1024,
                             compute_dtype=jnp.bfloat16):
    """Causal self-attention with q chunked along sequence.

    q: [B, S, H, D]; k, v: [B, S, Hkv, D] -> [B, S, H, D] (f32).
    Peak score memory: [B, H, chunk, S] instead of [B, H, S, S].
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    # grouped-GQA einsum: query heads fold into [Hkv, g]; K/V stay at Hkv
    # heads (materialising repeat_kv forces involuntary full
    # rematerialisation under GSPMD when Hkv < TP degree — §Perf baseline)
    k = k.astype(compute_dtype)
    v = v.astype(compute_dtype)
    q = q.astype(compute_dtype)
    scale = D ** -0.5
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk

    kT = k.transpose(0, 2, 3, 1)                     # [B, Hkv, D, S]
    vT = v.transpose(0, 2, 1, 3)                     # [B, Hkv, S, D]
    q5 = q.reshape(B, S, Hkv, g, D).transpose(1, 0, 2, 3, 4)
    qc = q5.reshape(n_chunks, chunk, B, Hkv, g, D)
    qc = qc.transpose(0, 2, 3, 4, 1, 5)              # [n, B, Hkv, g, c, D]

    cols = jnp.arange(S)

    # checkpointed: per-chunk score/prob tensors recomputed in backward
    @jax.checkpoint
    def body(_, args):
        i, qi = args                                  # qi: [B, Hkv, g, c, D]
        s = jnp.einsum("bkgcd,bkds->bkgcs", qi, kT,
                       preferred_element_type=jnp.float32) * scale
        rows = i * chunk + jnp.arange(chunk)
        mask = cols[None, :] <= rows[:, None]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(compute_dtype)
        o = jnp.einsum("bkgcs,bksd->bkgcd", p, vT,
                       preferred_element_type=jnp.float32)
        return None, o

    from repro.models import common as _cm
    _, out = _cm.scan(body, None, (jnp.arange(n_chunks), qc))
    # [n, B, Hkv, g, c, D] -> [B, (n c)=S, (Hkv g)=H, D]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, D)
    return out


def decode_attention(q, k_cache, v_cache, t_pos, compute_dtype=jnp.bfloat16):
    """Single-token decode attention against a full cache.

    q: [B, 1, H, D]; caches: [B, S, Hkv, D]; t_pos: current length (i32).
    Entries at position >= t_pos are masked.  Returns [B, 1, H, D] f32.
    """
    B, S, Hkv, D = k_cache.shape
    Q = q.shape[1]
    H = q.shape[2]
    g = H // Hkv
    k = k_cache.astype(compute_dtype)
    v = v_cache.astype(compute_dtype)
    scale = D ** -0.5
    q5 = q.astype(compute_dtype).reshape(B, Q, Hkv, g, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q5, k,
                   preferred_element_type=jnp.float32) * scale
    valid = (jnp.arange(S) < t_pos)[None, None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(compute_dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Q, H, D)


def bidirectional_attention(q, k, v, compute_dtype=jnp.bfloat16):
    """Full (non-causal) attention, used by the whisper encoder and
    cross-attention.  q: [B, Sq, H, D]; k, v: [B, Skv, Hkv, D]."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    k = repeat_kv(k, H // Hkv).astype(compute_dtype)
    v = repeat_kv(v, H // Hkv).astype(compute_dtype)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(compute_dtype), k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    p = jax.nn.softmax(s, axis=-1).astype(compute_dtype)
    return jnp.einsum("bhqs,bshd->bqhd", p, v,
                      preferred_element_type=jnp.float32)

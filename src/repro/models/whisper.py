"""Whisper-style encoder-decoder (arXiv:2212.04356) — transformer backbone
only; the conv/log-mel frontend is a STUB per the assignment: ``input_specs``
supplies precomputed frame embeddings [B, enc_frames, d].

Encoder: bidirectional self-attention over frames (+ learned positions).
Decoder: causal self-attention + cross-attention to encoder output.
Decode keeps a self-attn KV cache; cross K/V are computed once at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import common as cm


def _init_attn(key, d, H, hd, dt):
    ks = jax.random.split(key, 4)
    return {
        "wq": cm.dense_init(ks[0], d, H * hd, dt),
        "wk": cm.dense_init(ks[1], d, H * hd, dt),
        "wv": cm.dense_init(ks[2], d, H * hd, dt),
        "wo": cm.dense_init(ks[3], H * hd, d, dt),
    }


def _init_mlp(key, d, ff, dt):
    k1, k2 = jax.random.split(key)
    return {"w1": cm.dense_init(k1, d, ff, dt), "w2": cm.dense_init(k2, ff, d, dt)}


def init_enc_layer(cfg, key):
    dt = cfg.pdtype()
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dt), "b1": jnp.zeros((cfg.d_model,), dt),
        "attn": _init_attn(k1, cfg.d_model, cfg.n_heads, cfg.hd, dt),
        "ln2": jnp.ones((cfg.d_model,), dt), "b2": jnp.zeros((cfg.d_model,), dt),
        "mlp": _init_mlp(k2, cfg.d_model, cfg.d_ff, dt),
    }


def init_dec_layer(cfg, key):
    dt = cfg.pdtype()
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dt), "b1": jnp.zeros((cfg.d_model,), dt),
        "self": _init_attn(k1, cfg.d_model, cfg.n_heads, cfg.hd, dt),
        "lnx": jnp.ones((cfg.d_model,), dt), "bx": jnp.zeros((cfg.d_model,), dt),
        "cross": _init_attn(k2, cfg.d_model, cfg.n_heads, cfg.hd, dt),
        "ln2": jnp.ones((cfg.d_model,), dt), "b2": jnp.zeros((cfg.d_model,), dt),
        "mlp": _init_mlp(k3, cfg.d_model, cfg.d_ff, dt),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 5)
    dt = cfg.pdtype()
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "emb": cm.dense_init(ks[2], cfg.vocab, cfg.d_model, dt, scale=0.02),
        "enc_pos": cm.dense_init(ks[3], cfg.enc_frames, cfg.d_model, dt, scale=0.02),
        "enc": jax.vmap(lambda k: init_enc_layer(cfg, k))(enc_keys),
        "dec": jax.vmap(lambda k: init_dec_layer(cfg, k))(dec_keys),
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "bn_f": jnp.zeros((cfg.d_model,), dt),
    }


def _heads(cfg, y, B, S):
    return y.reshape(B, S, cfg.n_heads, cfg.hd)


def _gelu_mlp(cfg, mp, x):
    cd = cfg.cdtype()
    return cm.mm(jax.nn.gelu(cm.mm(x, mp["w1"], cd)), mp["w2"], cd)


def encode(cfg: ArchConfig, params, frames):
    """frames: [B, F, d] (stub embeddings) -> encoder output [B, F, d]."""
    B, F, d = frames.shape
    x = frames.astype(jnp.float32) + params["enc_pos"].astype(jnp.float32)[None]
    cd = cfg.cdtype()

    def body(x, lp):
        h = cm.layer_norm(x, lp["ln1"], lp["b1"])
        q = _heads(cfg, cm.mm(h, lp["attn"]["wq"], cd), B, F)
        k = _heads(cfg, cm.mm(h, lp["attn"]["wk"], cd), B, F)
        v = _heads(cfg, cm.mm(h, lp["attn"]["wv"], cd), B, F)
        o = attn.bidirectional_attention(q, k, v, cd)
        x = x + cm.mm(o.reshape(B, F, -1), lp["attn"]["wo"], cd)
        h = cm.layer_norm(x, lp["ln2"], lp["b2"])
        x = x + _gelu_mlp(cfg, lp["mlp"], h)
        return x, None

    x, _ = cm.scan(body, x, params["enc"])
    return x


def _dec_layer(cfg, lp, x, enc_kv, self_kv, t_pos, B, S, causal_full):
    """Shared decoder layer; self_kv None -> full-sequence causal."""
    cd = cfg.cdtype()
    h = cm.layer_norm(x, lp["ln1"], lp["b1"])
    q = _heads(cfg, cm.mm(h, lp["self"]["wq"], cd), B, S)
    k = _heads(cfg, cm.mm(h, lp["self"]["wk"], cd), B, S)
    v = _heads(cfg, cm.mm(h, lp["self"]["wv"], cd), B, S)
    if self_kv is None:
        o = attn.chunked_causal_attention(q, k, v, compute_dtype=cd)
        kc = vc = None
    else:
        kc, vc = self_kv
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), t_pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), t_pos, axis=1)
        o = attn.decode_attention(q, kc, vc, t_pos + 1, cd)
    x = x + cm.mm(o.reshape(B, S, -1), lp["self"]["wo"], cd)
    # cross attention to (precomputed) encoder K/V
    ek, ev = enc_kv
    h = cm.layer_norm(x, lp["lnx"], lp["bx"])
    q = _heads(cfg, cm.mm(h, lp["cross"]["wq"], cd), B, S)
    o = attn.bidirectional_attention(q, ek, ev, cd)
    x = x + cm.mm(o.reshape(B, S, -1), lp["cross"]["wo"], cd)
    h = cm.layer_norm(x, lp["ln2"], lp["b2"])
    x = x + _gelu_mlp(cfg, lp["mlp"], h)
    return x, kc, vc


def _enc_kv(cfg, params, enc_out):
    """Per-layer cross K/V from encoder output: [L, B, F, H, hd] x2."""
    B, F, _ = enc_out.shape
    cd = cfg.cdtype()

    def one(lp):
        k = _heads(cfg, cm.mm(enc_out, lp["cross"]["wk"], cd), B, F)
        v = _heads(cfg, cm.mm(enc_out, lp["cross"]["wv"], cd), B, F)
        return k, v

    return jax.vmap(one)(params["dec"])


def forward(cfg: ArchConfig, params, tokens, frames, attn_chunk=1024):
    """Training/prefill forward: returns decoder hidden states [B, S, d]."""
    B, S = tokens.shape
    enc_out = encode(cfg, params, frames)
    ek, ev = _enc_kv(cfg, params, enc_out)
    x = params["emb"][tokens].astype(jnp.float32)

    def body(x, layer):
        lp, eki, evi = layer
        x, _, _ = _dec_layer(cfg, lp, x, (eki, evi), None, 0, B, S, True)
        return x, None

    x, _ = cm.scan(body, x, (params["dec"], ek, ev))
    return cm.layer_norm(x, params["ln_f"], params["bn_f"])


def decode_step(cfg: ArchConfig, params, token, cache, t_pos):
    """cache: dict(k, v: [L, B, S, H, hd] self caches; ek, ev cross K/V)."""
    B = token.shape[0]
    x = params["emb"][token].astype(jnp.float32)

    def body(x, layer):
        lp, kc, vc, eki, evi = layer
        x, kc, vc = _dec_layer(cfg, lp, x, (eki, evi), (kc, vc), t_pos, B, 1, False)
        return x, (kc, vc)

    x, (kc, vc) = cm.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["ek"], cache["ev"]))
    x = cm.layer_norm(x, params["ln_f"], params["bn_f"])
    logits = cm.mm(x, params["emb"].T, cfg.cdtype())
    return logits, dict(cache, k=kc, v=vc)


def make_cache(cfg: ArchConfig, batch, seq_len, frames=None, dtype=None):
    dtype = dtype or cfg.cdtype()
    L, H, hd = cfg.n_layers, cfg.n_heads, cfg.hd
    F = cfg.enc_frames
    return {
        "k": jnp.zeros((L, batch, seq_len, H, hd), dtype),
        "v": jnp.zeros((L, batch, seq_len, H, hd), dtype),
        "ek": jnp.zeros((L, batch, F, H, hd), dtype),
        "ev": jnp.zeros((L, batch, F, H, hd), dtype),
    }

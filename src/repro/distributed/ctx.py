"""Activation-sharding context.

Model code calls ``constrain(x, "residual")`` etc.; outside a sharding
context (CPU smoke tests, single device) this is a no-op, inside the dry-run
/ launcher it applies ``with_sharding_constraint`` with the mesh-appropriate
PartitionSpec (sequence parallelism on the residual stream, batch sharding on
token streams, expert sharding on MoE buffers).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _specs(dp, tp):
    """dp: tuple of data axes (('pod','data') or ('data',)); tp: 'model'."""
    return {
        # [B, S, d] residual stream: batch over dp, sequence over tp (SP)
        "residual": P(dp, tp, None),
        # [B, S, d] without SP (pre-attention gathered form)
        "tokens3d": P(dp, None, None),
        # [B, S] token ids
        "tokens": P(dp, None),
        # [B, 1, d] decode hidden
        "decode_hidden": P(dp, None, None),
        # MoE dispatch buffer [G, E, C, d]: groups over data, experts over
        # model — the transition between the two IS the MoE all-to-all
        "moe_buf": P(dp, tp, None, None),
        # attention heads [B, S, H, hd]
        "heads": P(dp, None, tp, None),
    }


def _mesh_ctx(mesh):
    """Context mesh across jax versions: jax.set_mesh (new), else
    jax.sharding.use_mesh, else the Mesh object itself (jax 0.4.x)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


@contextlib.contextmanager
def sharding_ctx(mesh):
    """Enable activation constraints for a (pod,)data,model mesh.
    Also installs the mesh as jax's context mesh so PartitionSpec-based
    ``with_sharding_constraint`` resolves."""
    axes = mesh.axis_names
    dp = tuple(a for a in axes if a in ("pod", "data"))
    specs = _specs(dp, "model")
    prev = getattr(_state, "specs", None)
    _state.specs = specs
    try:
        with _mesh_ctx(mesh):
            yield
    finally:
        _state.specs = prev


def constrain(x, kind: str):
    specs = getattr(_state, "specs", None)
    if specs is None or kind not in specs:
        return x
    spec = specs[kind]
    if len(spec) > x.ndim:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x

"""Distribution layer: mesh-aware sharding rules (FSDP/TP/SP/EP), activation
sharding constraints, and the SPMD FAP simulation round for the paper's own
workload."""
from repro.distributed.ctx import sharding_ctx, constrain  # noqa: F401

"""Distribution layer: mesh-aware sharding rules (FSDP/TP/SP/EP), activation
sharding constraints, the pluggable spike-parcel exchange transports, and
the SPMD FAP simulation round for the paper's own workload."""
from repro.distributed.ctx import sharding_ctx, constrain  # noqa: F401
from repro.distributed.exchange import (ExchangeSpec, Transport,  # noqa: F401
                                        get_transport)
from repro.distributed.placement import (Placement,  # noqa: F401
                                         compute_placement, place_network,
                                         unpermute_result)

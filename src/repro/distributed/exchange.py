"""Pluggable spike-parcel transport for the SPMD FAP round.

The paper's asynchronous execution model has exactly two point-to-point
channels — stepping notifications (neuron clocks) and spike parcels — and
the efficiency claim rests on their cost scaling with *activity*, not
network size.  This module makes the channel realisation a first-class
knob (``transport="allgather"|"sparse"`` on ``build_fap_round``):

``allgather`` (reference)
    Both channels are dense all-gathers of full N-length vectors, exactly
    the collectives GSPMD would insert: bytes scale with N regardless of
    firing rate.

``sparse`` (the activity-scaled transport)
    * spike parcels: each shard compacts its (spiked, t_spike) into a
      destination-routed parcel buffer [n_shards, parcel_cap] of
      (global id, time) entries via the sort-free cumsum-rank compaction
      kernel (``kernels.event_wheel.ops.spike_compact``), then exchanges
      rows with one tiled ``all_to_all``: per-device parcel bytes are
      ``n_shards * parcel_cap * (4 + 8)`` — a function of the static
      activity cap, independent of N.  Parcel-cap overflow is detected,
      never silent: the per-round drop counter rides the round outputs
      (``RunResult.dropped`` via ``run_fap_spmd``).
    * clock notifications: an all-gather over each shard's *boundary set*
      (local neurons with cross-shard out-edges — the static frontier
      ``sharding.shard_frontier`` derives at build time from the by-post
      edge layout), scattered back into an N-length clock table.  For
      spatially local connectivity the frontier, and hence notify bytes,
      shrinks far below N; for uniform random wiring it degenerates to
      ~N (every neuron is boundary), which the channel attribution makes
      visible instead of hiding.  Locality is *manufactured* one layer up:
      structured topologies (``repro.core.topology``) plus a
      locality-aware id permutation (``distributed.placement``, the
      ``placement=`` knob on ``run_fap_spmd``) hand this transport an edge
      list whose frontier — and notify gather — is already small; the
      transport itself needs no placement awareness because the routing
      tables are derived from whatever (relabeled) net it is given.

``sparse_ragged`` (the two-phase activity-sized transport)
    The static per-(src,dst) parcel cap wastes slots on quiet pairs.  The
    ragged transport exchanges *counts* first (one scalar ``pmax`` over
    the per-destination spike counts, tagged ``exchange_counts``), then
    runs the parcel ``all_to_all`` at the smallest *bucket class* — a
    static ascending ladder of caps ending at ``parcel_cap``
    (``ExchangeSpec.classes``) — that fits this round's fullest shard
    pair.  Shapes stay static per class (one ``lax.switch`` branch each,
    tagged ``exchange_parcel_c<cap>`` so the per-class bytes are
    HLO-attributable); quiet rounds ship the smallest class, ~2-4x fewer
    parcel bytes, and no round ever ships more than the static cap.
    Overflow semantics are identical to ``sparse``: events beyond
    ``parcel_cap`` are counted in ``dropped``, never silent.

Every collective is wrapped in ``jax.named_scope`` with a channel tag
(``exchange_notify`` / ``exchange_parcel`` / ``exchange_counts``) that
survives into compiled HLO metadata, so
``launch.hlo_analysis.collective_channel_bytes`` can *assert* the
bytes-scale-with-activity claim per channel rather than assume it.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

NOTIFY_TAG = "exchange_notify"
PARCEL_TAG = "exchange_parcel"
COUNTS_TAG = "exchange_counts"
TRANSPORTS = ("allgather", "sparse", "sparse_ragged")


def class_tag(cap: int) -> str:
    """HLO-query tag for one ragged bucket class's parcel scope.

    Includes the trailing scope delimiter so per-class attribution cannot
    alias across classes whose caps share a decimal prefix
    ("exchange_parcel_c1" is a substring of "exchange_parcel_c12"; the
    op_name path always delimits the scope with "/")."""
    return f"{PARCEL_TAG}_c{cap}/"


class ExchangeSpec(NamedTuple):
    """Static sparse-transport geometry (python constants, closed over by
    jit — the ``WheelSpec`` of the communication layer)."""
    parcel_cap: int = 64          # parcel slots per (source, dest) shard pair
    compact_impl: str = "pallas"  # spike_compact dispatch: "pallas" | "jnp"
    classes: tuple = ()           # ragged bucket-class caps (ascending);
    #                               () -> (cap//8, cap//2, cap) deduped

    def class_ladder(self) -> tuple:
        """The realized ascending class ladder, always ending at
        ``parcel_cap`` (so the largest class is exactly the static
        transport's geometry and ragged can never ship more)."""
        cap = int(self.parcel_cap)
        base = self.classes or (max(1, cap // 8), max(1, cap // 2))
        return tuple(sorted({int(c) for c in base if 0 < int(c) < cap})) \
            + (cap,)


class Transport(NamedTuple):
    """One realisation of the two FAP notification channels.

    ``notify``/``exchange`` run *inside* shard_map on shard-local arrays;
    ``example_args``/``in_specs``/``shardings`` describe the transport's
    extra static-routing arguments (empty for the dense reference).
    """
    name: str
    notify: Callable       # (t_local, *targs) -> (f64[N] global clock table,
    #                         aux: gathered boundary clock vector for the
    #                         sparse family, None for allgather — the
    #                         incremental-horizon moved-set source)
    exchange: Callable     # (spiked_l, t_sp_l, *targs) ->
    #                         (spiked bool[N], t_spike f64[N], local drops
    #                          i32, parcel bytes shipped this round i32)
    example_args: tuple    # transport arg arrays, appended to the round args
    in_specs: tuple        # shard_map PartitionSpecs for those args
    shardings: tuple       # jit NamedShardings for those args


def _flat_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def _gather_axes(x, flat):
    for ax in reversed(flat):
        x = jax.lax.all_gather(x, ax, tiled=True)
    return x


def shard_index(mesh, flat):
    """Flat shard index of the calling device inside shard_map (row-major
    over the given mesh axes) — shared by the transports and the
    shard-local round."""
    idx = jnp.zeros((), jnp.int32)
    for ax in flat:
        idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
    return idx


def allgather_transport(mesh) -> Transport:
    """The reference transport: both channels as dense N-length gathers."""
    flat = _flat_axes(mesh)

    def notify(t_local):
        with jax.named_scope(NOTIFY_TAG):
            return _gather_axes(t_local, flat), None

    def exchange(spiked, t_sp):
        with jax.named_scope(PARCEL_TAG):
            spiked_all = _gather_axes(spiked, flat)
            tsp_all = _gather_axes(t_sp, flat)
        n = spiked_all.shape[0]
        return (spiked_all, tsp_all, jnp.zeros((), jnp.int32),
                jnp.asarray(n * (1 + 8), jnp.int32))

    return Transport("allgather", notify, exchange, (), (), ())


def sparse_transport(mesh, n: int, net, spec: ExchangeSpec,
                     ragged: bool = False) -> Transport:
    """Activity-scaled transport: frontier-gather notify + capped
    destination-routed parcel ``all_to_all``.  Routing tables are derived
    host-side from the concrete edge list (``net``) at build time.

    ``ragged=True`` (``transport="sparse_ragged"``) sizes the parcel
    exchange per round: a counts phase (scalar ``pmax`` of the fullest
    (src, dst) pair) picks the smallest static bucket class
    (``spec.class_ladder()``) that fits, and only that class's sized
    ``all_to_all`` runs (``lax.switch``).  Semantics are identical to the
    static-cap exchange — the chosen class always covers every pending
    parcel entry up to ``parcel_cap``, and overflow beyond ``parcel_cap``
    hits the same drop counter."""
    from repro.distributed.sharding import shard_frontier
    from repro.kernels.event_wheel import ops as ew_ops

    flat = _flat_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in flat]))
    n_local = n // n_shards
    cap = int(spec.parcel_cap)
    classes = spec.class_ladder() if ragged else (cap,)
    fr = shard_frontier(np.asarray(net.pre), np.asarray(net.post), n, n_shards)
    b_rel = jnp.asarray(fr.boundary_rel)            # i32[n_shards, F] sharded
    b_gid = jnp.asarray(fr.boundary_gid)            # i32[n_shards, F] replicated
    dest_map = jnp.asarray(fr.dest_map)             # bool[N, n_shards] sharded

    def notify(t_local, b_rel_l, b_gid_all, dest_l):
        del dest_l
        with jax.named_scope(NOTIFY_TAG):
            mine = t_local[jnp.clip(b_rel_l[0], 0, n_local - 1)]      # [F]
            allv = _gather_axes(mine, flat)                # [n_shards * F]
            table = jnp.full((n,), jnp.inf, t_local.dtype)
            # pad slots carry the gid sentinel n -> parked out of range
            table = table.at[b_gid_all.reshape(-1)].set(allv, mode="drop")
            offset = shard_index(mesh, flat) * n_local
            table = jax.lax.dynamic_update_slice(table, t_local, (offset,))
        return table, allv

    def _ship(gid, ts, c_cap):
        """One sized parcel exchange: the first ``c_cap`` slots of every
        (src, dst) parcel row, padded back to the static cap after the
        collective so every class branch has one output shape."""
        tag = PARCEL_TAG if not ragged else f"{PARCEL_TAG}_c{c_cap}"
        with jax.named_scope(tag):
            gid_r = jax.lax.all_to_all(gid[:, :c_cap], flat, 0, 0, tiled=True)
            ts_r = jax.lax.all_to_all(ts[:, :c_cap], flat, 0, 0, tiled=True)
        pad = cap - c_cap
        if pad:
            gid_r = jnp.concatenate(
                [gid_r, jnp.full((n_shards, pad), n, gid_r.dtype)], axis=1)
            ts_r = jnp.concatenate(
                [ts_r, jnp.zeros((n_shards, pad), ts_r.dtype)], axis=1)
        return gid_r, ts_r, jnp.asarray(n_shards * c_cap * (4 + 8), jnp.int32)

    def exchange(spiked, t_sp, b_rel_l, b_gid_all, dest_l):
        del b_rel_l, b_gid_all
        # row d of the parcel buffer = this shard's spikes with at least
        # one synapse into shard d (deduped by the static dest map)
        mask = jnp.logical_and(dest_l, spiked[:, None]).T  # [S, n_local]
        vals = jnp.broadcast_to(t_sp[None, :], mask.shape)
        idx, ts, cnt = ew_ops.spike_compact(mask, vals, cap,
                                            impl=spec.compact_impl)
        offset = shard_index(mesh, flat) * n_local
        gid = jnp.where(idx < n_local, idx + offset, n)  # sentinel -> n
        if len(classes) == 1:
            gid_r, ts_r, pbytes = _ship(gid, ts, classes[0])
        else:
            # phase 1: global fullest (src, dst) pair -> smallest class
            with jax.named_scope(COUNTS_TAG):
                worst = jax.lax.pmax(jnp.max(cnt), flat)
            cidx = sum((worst > c).astype(jnp.int32) for c in classes[:-1])
            gid_r, ts_r, pbytes = jax.lax.switch(
                cidx, [(lambda g, t, c=c: _ship(g, t, c)) for c in classes],
                gid, ts)
        with jax.named_scope(PARCEL_TAG):
            spiked_all = jnp.zeros((n,), bool).at[gid_r.reshape(-1)].set(
                True, mode="drop")
            tsp_all = jnp.zeros((n,), t_sp.dtype).at[gid_r.reshape(-1)].set(
                ts_r.reshape(-1), mode="drop")
        drops = jnp.sum(jnp.maximum(cnt - cap, 0)).astype(jnp.int32)
        return spiked_all, tsp_all, drops, pbytes

    rowspec = P(flat, None)
    return Transport(
        "sparse_ragged" if ragged else "sparse", notify, exchange,
        example_args=(b_rel, b_gid, dest_map),
        in_specs=(rowspec, P(None, None), rowspec),
        shardings=(NamedSharding(mesh, rowspec),
                   NamedSharding(mesh, P(None, None)),
                   NamedSharding(mesh, rowspec)),
    )


def get_transport(name: str, mesh, *, n: int, net=None,
                  spec: ExchangeSpec = ExchangeSpec()) -> Transport:
    """Transport dispatch — the ``transport=`` knob."""
    if name == "allgather":
        return allgather_transport(mesh)
    if name in ("sparse", "sparse_ragged"):
        if net is None:
            raise ValueError(f"transport={name!r} derives its routing tables "
                             "from the concrete edge list: pass net=")
        return sparse_transport(mesh, n, net, spec,
                                ragged=name == "sparse_ragged")
    raise ValueError(f"unknown transport {name!r} (want one of {TRANSPORTS})")

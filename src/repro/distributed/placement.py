"""Locality-aware neuron-to-shard placement for the SPMD FAP round.

The round block-shards neurons by global id (shard of gid g = g // n_local),
so *which ids sit together* decides the communication bill: every neuron
with a cross-shard out-edge joins the notify frontier
(``sharding.shard_frontier``) and every cross-shard edge routes spike
parcels off-shard.  With uniform-random wiring every neuron is boundary —
the documented worst case — but on structured nets
(``repro.core.topology``) a relabeling can place most of each neuron's
neighbourhood on its own shard.

Placement is expressed as a *permutation of neuron ids* applied before
sharding and inverted on outputs: the four execution models and the SPMD
round run completely unmodified on the permuted ids (``place_network``
keeps the grouped by-post edge layout, so the grouped queue-insert fast
paths and ``WheelSpec.auto`` hold), and ``unpermute_result`` restores the
original neuron order on the spike record — runs are event-for-event
identical to the unpermuted anchor, only cheaper to communicate.

Passes (``compute_placement(method=...)``):

``identity``
    No relabeling (the baseline the benchmarks compare against).
``block``
    Contiguous-block pass: stable-sort neurons by their topology block id
    so each locality unit maps to a contiguous id range, hence (blocks
    dividing evenly) to whole shards.  Exactly recovers native block
    locality on label-shuffled nets.
``greedy``
    Greedy edge-cut refinement on top of ``block`` (or identity when the
    net carries no block metadata): balanced pairwise swap passes — each
    neuron scores its edge count per shard, positive-gain movers between
    shard pairs are matched and swapped, keeping shard sizes exactly equal.
    A Kernighan-Lin-flavoured descent: never increases the cut.
``auto``
    ``greedy`` when block metadata exists, else ``identity``.

Cut edges are *counted, not estimated* (``cut_edges``), and the realized
frontier under a placement is measured by ``frontier_stats`` through the
same ``shard_frontier`` tables the sparse transport ships.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

PLACEMENTS = ("identity", "block", "greedy", "auto")


class Placement(NamedTuple):
    """A neuron relabeling: old id g lands at new id ``perm[g]`` (and
    ``inv[perm[g]] == g``), sharded as new_id // (n // n_shards)."""
    perm: np.ndarray       # i32[N] old -> new
    inv: np.ndarray        # i32[N] new -> old
    n_shards: int
    method: str
    cut: int               # realized cross-shard edges under this placement

    @property
    def n(self) -> int:
        return int(self.perm.shape[0])


def shard_of(ids: np.ndarray, n: int, n_shards: int,
             perm: Optional[np.ndarray] = None) -> np.ndarray:
    """Shard of each (optionally relabeled) neuron id under block sharding."""
    ids = np.asarray(ids, np.int64)
    if perm is not None:
        ids = np.asarray(perm, np.int64)[ids]
    return ids // (n // n_shards)


def cut_edges(pre: np.ndarray, post: np.ndarray, n: int, n_shards: int,
              perm: Optional[np.ndarray] = None) -> int:
    """Number of edges whose endpoints land on different shards."""
    return int((shard_of(pre, n, n_shards, perm)
                != shard_of(post, n, n_shards, perm)).sum())


def _perm_from_order(order: np.ndarray):
    """order[j] = old id placed at new id j  ->  (perm, inv)."""
    inv = np.asarray(order, np.int32)
    perm = np.empty_like(inv)
    perm[inv] = np.arange(inv.shape[0], dtype=np.int32)
    return perm, inv


def from_order(order: np.ndarray, n_shards: int, net=None,
               method: str = "external") -> Placement:
    """Wrap an explicit new-id ordering (order[j] = old id at new id j) as
    a Placement; the cut is counted when the concrete net is given (e.g.
    label-shuffle permutations in benchmarks/tests)."""
    perm, inv = _perm_from_order(order)
    cut = 0 if net is None else cut_edges(np.asarray(net.pre),
                                          np.asarray(net.post), int(net.n),
                                          n_shards, perm)
    return Placement(perm=perm, inv=inv, n_shards=n_shards, method=method,
                     cut=cut)


def _greedy_refine(pre, post, assign, n_shards, passes: int = 3):
    """Balanced pairwise-swap descent on the edge cut.

    Each pass scores conn[i, s] = edges between neuron i and shard s (both
    directions), then matches positive-gain movers between shard pairs
    (i: a->b with j: b->a, best gains first) and swaps them — shard sizes
    never change.  Gains are recomputed between passes; within a pass they
    are stale after a swap (e.g. two mutually-connected movers swapped into
    each other's shard keep their shared edges cut), so the cut is
    re-counted after every pass and the best assignment seen — including
    the starting one — is returned: refinement never loses locality.
    """
    n = assign.shape[0]
    assign = assign.copy()
    pre = np.asarray(pre, np.int64)
    post = np.asarray(post, np.int64)

    def cut_of(a):
        return int((a[pre] != a[post]).sum())

    best_assign, best_cut = assign.copy(), cut_of(assign)
    for _ in range(passes):
        conn = np.zeros((n, n_shards), np.int64)
        np.add.at(conn, (pre, assign[post]), 1)
        np.add.at(conn, (post, assign[pre]), 1)
        cur = conn[np.arange(n), assign]
        best = conn.argmax(axis=1).astype(assign.dtype)
        gain = conn.max(axis=1) - cur
        movers = np.flatnonzero((best != assign) & (gain > 0))
        by_pair: dict = {}
        for i in movers:
            by_pair.setdefault((int(assign[i]), int(best[i])), []).append(i)
        swapped = 0
        for (a, b), fwd in sorted(by_pair.items()):
            if a >= b:
                continue
            rev = by_pair.get((b, a), [])
            fwd = sorted(fwd, key=lambda i: -gain[i])
            rev = sorted(rev, key=lambda i: -gain[i])
            for i, j in zip(fwd, rev):
                assign[i], assign[j] = b, a
                swapped += 1
        if not swapped:
            break
        c = cut_of(assign)
        if c < best_cut:
            best_assign, best_cut = assign.copy(), c
    return best_assign


def compute_placement(net, n_shards: int, method: str = "auto",
                      passes: int = 3) -> Placement:
    """Derive a shard placement for ``net`` (see module docstring)."""
    n = int(net.n)
    if n % n_shards:
        raise ValueError(f"n={n} not divisible by n_shards={n_shards}")
    if method not in PLACEMENTS:
        raise ValueError(f"unknown placement {method!r} "
                         f"(want one of {PLACEMENTS})")
    if method == "auto":
        method = "greedy" if net.block is not None else "identity"
    pre, post = np.asarray(net.pre), np.asarray(net.post)
    if method == "identity" or (method == "block" and net.block is None):
        order = np.arange(n, dtype=np.int32)
        method_out = "identity"
    else:
        if net.block is not None:
            order = np.argsort(np.asarray(net.block), kind="stable")
        else:
            order = np.arange(n, dtype=np.int64)
        if method == "greedy":
            perm0, _ = _perm_from_order(order)
            assign = shard_of(np.arange(n), n, n_shards, perm0)
            assign = _greedy_refine(pre, post, assign.astype(np.int32),
                                    n_shards, passes=passes)
            # contiguify: stable sort by refined shard keeps each shard's
            # neurons in block order within its id range
            order = np.argsort(assign, kind="stable")
        method_out = method
    perm, inv = _perm_from_order(order)
    cut = cut_edges(pre, post, n, n_shards, perm)
    return Placement(perm=perm, inv=inv, n_shards=n_shards,
                     method=method_out, cut=cut)


# ---------------------------------------------------------------------------
# applying / inverting a placement
# ---------------------------------------------------------------------------
def place_network(net, pl: Placement):
    """Relabel a network's neuron ids by ``pl.perm``.

    The grouped by-post layout is preserved (new neuron j's in-edge group
    is old neuron inv[j]'s group with pre relabeled), so every grouped
    fast path sees the same static structure.  Non-grouped edge lists are
    relabeled and re-sorted by new post (stable).
    """
    n, E = int(net.n), int(net.pre.shape[0])
    perm, inv = pl.perm, pl.inv
    pre = np.asarray(net.pre)
    post = np.asarray(net.post)
    block = None if net.block is None else np.asarray(net.block)[inv]
    k = E // n if E % n == 0 else 0
    grouped = k > 0 and np.array_equal(
        post, np.repeat(np.arange(n, dtype=post.dtype), k))
    if grouped:
        def regroup(a):
            return np.asarray(a).reshape(n, k)[inv].reshape(-1)
        pre2 = regroup(perm[pre])
        post2 = np.repeat(np.arange(n, dtype=post.dtype), k)
        delay2, wa2, wg2 = map(regroup, (net.delay, net.w_ampa, net.w_gaba))
    else:
        pre2, post2 = perm[pre], perm[post]
        order = np.argsort(post2, kind="stable")
        pre2, post2 = pre2[order], post2[order]
        delay2 = np.asarray(net.delay)[order]
        wa2 = np.asarray(net.w_ampa)[order]
        wg2 = np.asarray(net.w_gaba)[order]
    return net._replace(pre=pre2, post=post2, delay=delay2, w_ampa=wa2,
                        w_gaba=wg2, block=block)


def permute_dense(x, pl: Placement):
    """Per-neuron vector/rows old order -> new order (e.g. iinj)."""
    x = np.asarray(x)
    if x.ndim == 0 or x.shape[0] != pl.n:
        return x                          # scalars broadcast unchanged
    return x[pl.inv]


def unpermute_rows(x, pl: Placement):
    """Per-neuron rows of a permuted run back to the original order."""
    return x[pl.perm]


def unpermute_result(res, pl: Placement):
    """Restore original neuron order on a RunResult from a permuted run."""
    rec = res.rec._replace(times=res.rec.times[pl.perm],
                           count=res.rec.count[pl.perm])
    y = res.y_final
    if getattr(y, "ndim", 0) >= 1 and y.shape[0] == pl.n:
        y = y[pl.perm]
    return res._replace(rec=rec, y_final=y)


def place_inputs(net, iinj, pl: Placement):
    """(net, iinj) relabeled for a placed run."""
    return place_network(net, pl), permute_dense(iinj, pl)


# ---------------------------------------------------------------------------
# realized-locality measurement (through the transport's own tables)
# ---------------------------------------------------------------------------
def frontier_stats(net, n_shards: int,
                   pl: Optional[Placement] = None) -> dict:
    """Measured notify-frontier statistics under an (optional) placement.

    Derived from the same ``shard_frontier`` tables the sparse transport
    ships, on the relabeled edge list: F (the padded per-shard frontier
    width that sizes the notify gather), the true per-shard boundary
    counts, the boundary fraction of N, and the cut-edge count/fraction.
    """
    from repro.distributed.sharding import shard_frontier

    n = int(net.n)
    pre, post = np.asarray(net.pre), np.asarray(net.post)
    perm = None if pl is None else pl.perm
    fr = shard_frontier(pre, post, n, n_shards, perm=perm)
    sizes = fr.sizes
    cut = cut_edges(pre, post, n, n_shards, perm)
    return {
        "F": int(fr.frontier_size),
        "sizes": sizes.astype(int).tolist(),
        "boundary_frac": float(sizes.sum() / n),
        "cut_edges": cut,
        "cut_frac": float(cut / max(1, pre.shape[0])),
    }

"""SPMD (multi-pod) realisation of the FAP variable-timestep scheduler round.

This is the paper's execution model mapped onto the production mesh
(DESIGN.md §3): neurons (and their BDF integrator states, event queues and
out-edge lists) are sharded across every mesh axis; one jitted ``fap_round``
advances all runnable neurons to their dependency horizons.

The round decomposes into five composable stages, shared with the
single-host execution models through ``exec_common`` and ``repro.sched``:

  notify   — exchange neuron clocks (the paper's stepping notifications):
             ``distributed.exchange.Transport.notify``
  horizon  — per-neuron dependency horizon + runnable mask:
             ``exec_common.horizon_times`` / ``runnable_mask`` (the same
             helper the single-host exec models call, here with the
             shard-relative post index and the notify clock table)
  advance  — per-neuron variable-order variable-step BDF to the horizon:
             ``exec_bsp.make_vardt_advance`` (unchanged, vmapped)
  parcels  — exchange (spiked, t_spike): ``Transport.exchange``
  insert   — shard-local grouped queue insert (``repro.sched``; with
             queue="wheel" the bucketed O(E) scatter, no sort anywhere)

Communication is owned entirely by the transport (the
``transport="allgather"|"sparse"`` knob, mirroring the ``queue`` knob):

  * ``allgather`` — the reference realisation: both channels as dense
    all-gathers of full N-length vectors (bytes scale with N),
  * ``sparse``   — capped destination-routed parcel ``all_to_all`` plus a
    boundary-set notify gather (parcel bytes scale with the static
    activity cap, not N).  Requires ``optimized=True`` and the concrete
    ``net`` (routing tables are static, derived at build time).

Each channel's collectives are tagged with ``jax.named_scope`` so
``launch.hlo_analysis.collective_channel_bytes`` attributes per-channel
bytes in the compiled HLO — the bytes-scale-with-activity claim is
asserted by tests/benchmarks, not assumed.

Parcel-cap and queue overflow stay detected-never-silent: every round
returns a ``dropped`` counter (queue + transport), accumulated into
``RunResult.dropped`` by ``run_fap_spmd``.

``build_fap_round`` returns (fn, example_args, in_shardings) so the dry-run
can lower it on the 16x16 and 2x16x16 meshes like any LM cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import sched
from repro.core import bdf
from repro.core import exec_common as xc
from repro.core.cell import CellModel
from repro.core.exec_bsp import make_vardt_advance
from repro.distributed.exchange import (ExchangeSpec, get_transport,
                                        shard_index)


class PaperNeuroSpec(NamedTuple):
    n_neurons: int = 1 << 20          # 2^20 neurons (4x the paper's 219k lab run)
    k_in: int = 16
    n_comp: int = 29                  # branched_tree(depth=3)
    ev_cap: int = 32
    t_end: float = 1000.0
    horizon_cap: float = 2.0


def build_fap_round(model: CellModel, spec: PaperNeuroSpec, mesh,
                    opts: bdf.BDFOptions = bdf.BDFOptions(),
                    optimized: bool = False, queue: str = "dense",
                    wheel: sched.WheelSpec = sched.WheelSpec(),
                    transport: str = "allgather",
                    exchange: ExchangeSpec = ExchangeSpec(), net=None,
                    batch: str = "dense", batch_cap: int = 0,
                    fanout: str = "dense", spike_cap: int = 0,
                    horizon: str = "full", move_cap: int = 0):
    """optimized=False: paper-faithful baseline — horizon scatter-min and
    event insert as *global* ops, lowered by GSPMD (collective-heavy: with
    queue="dense" the global argsort in the insert becomes a distributed
    sort; queue="wheel" already removes the sort from the global path).

    optimized=True (§Perf): the communication is exactly the paper's two
    notification channels and nothing else, realised by the chosen
    transport (see module docstring); horizon computation and queue
    insertion run SHARD-LOCAL inside shard_map (edges are sharded by
    postsynaptic neuron, aligned with the neuron sharding, so no event
    ever crosses shards again).  With queue="wheel" the shard-local insert
    is the bucketed event-wheel scatter (repro.sched) — no sort of any
    kind, local or distributed.

    batch="compact" compacts each shard's runnable mask into a local
    gather-id list and advances only a fixed [batch_cap]-wide batch per
    round (earliest-clock threshold selection on overflow, exactly the
    single-host ``exec_fap`` semantics, here per shard) — composing with
    the sparse transport (spiked/t_spike are scattered back to full
    shard-width before the parcel exchange) and with placement (locality
    shrinks the frontier the compact batch has to cover).  Shard-local
    only: with ``optimized=False`` there is no shard-local stage to
    compact and the knob is rejected.

    fanout="compact" compacts each round's (global) spiking set and
    gathers only those neurons' out-edges from the replicated static
    ``exec_common.out_edge_table``; each shard keeps the rows that land in
    its contiguous global-edge-id slice and inserts that fixed
    [spike_cap * k_out] batch instead of scanning all E/n_shards local
    in-edges — the delivery-side twin of ``batch="compact"``.  More than
    ``spike_cap`` global spikes fall back to the dense insert under
    ``lax.cond`` (identical events, never a drop).  spike_cap <= 0 means
    min(N, 256).

    horizon="incremental" extends PR 4's incremental horizon maintenance
    to the shard-local round: the moved set is (a) last round's advanced
    batch (carried compact ids) and (b) the *notify frontier* entries
    whose gathered clock changed this round (compared against the carried
    previous boundary-clock vector — ``sharding.shard_frontier`` tables),
    and only rows fed by a moved clock recompute, bit-identical to the
    full scatter-min because min is exact.  Requires optimized +
    batch="compact" + a sparse-family transport (the frontier tables).
    The round then carries (horizon, prev_boundary_clocks, moved_ids)
    through its inputs/outputs; ``run_fap_spmd`` seeds them.

    The round returns (sts, eq_t, eq_a, eq_g, spiked, t_spike, n_deliv,
    n_resets, dropped, parcel_bytes[, horizon, prev_bnd, moved_ids]);
    ``dropped`` counts this round's queue overflow plus sparse-transport
    parcel overflow (detected, never silent); ``parcel_bytes`` is the
    transport's realized parcel-channel payload this round (the ragged
    transport's per-round class choice made visible — cross-checked
    against the per-class HLO attribution in tests).
    """
    from functools import partial

    from jax.experimental.shard_map import shard_map

    if transport != "allgather" and not optimized:
        raise ValueError("sparse transport realises the shard-local "
                         "(optimized=True) round; the global path has no "
                         "explicit channels to replace")
    if batch not in ("dense", "compact"):
        raise ValueError(f"unknown batch mode {batch!r}")
    if batch == "compact" and not optimized:
        raise ValueError("active-set compaction is shard-local "
                         "(optimized=True); the global path has no "
                         "shard-local advance stage to compact")
    if fanout not in ("dense", "compact"):
        raise ValueError(f"unknown fanout mode {fanout!r}")
    if fanout == "compact" and (not optimized or net is None):
        raise ValueError("compact fan-out is shard-local (optimized=True) "
                         "and derives its out-edge table from the concrete "
                         "edge list: pass net=")
    if horizon not in ("full", "incremental"):
        raise ValueError(f"unknown horizon mode {horizon!r}")
    incremental = horizon == "incremental"
    if incremental and (not optimized or batch != "compact"
                        or not transport.startswith("sparse")
                        or net is None):
        raise ValueError("incremental horizon maintenance needs the "
                         "shard-local round (optimized=True), the compact "
                         "batch's moved set (batch='compact') and the "
                         "sparse transport's frontier tables "
                         "(transport='sparse'|'sparse_ragged', net=)")
    n, E = spec.n_neurons, spec.n_neurons * spec.k_in
    flat = tuple(mesh.axis_names)                  # shard over ALL axes
    nshard = P(flat)
    advance = make_vardt_advance(model, opts, eg_window=0.0, step_budget=8)
    vadvance = jax.vmap(advance)
    n_shards = int(np.prod([mesh.shape[a] for a in flat]))
    n_local = n // n_shards
    e_local = E // n_shards
    cap = n_local if batch_cap <= 0 else min(int(batch_cap), n_local)
    s_cap = min(int(spike_cap), n) if spike_cap > 0 else min(n, 256)
    qops = sched.get_queue_ops(queue, ev_cap=spec.ev_cap, wheel=wheel)
    qcap = qops.capacity
    tp = get_transport(transport, mesh, n=n, net=net, spec=exchange) \
        if optimized else None
    n_targs = len(tp.example_args) if tp is not None else 0
    # replicated static tables (appended to the round args after targs);
    # one host-side grouping pass serves both views
    tbl_args, tbl_specs = (), ()
    if fanout == "compact" or incremental:
        post_np, edge_np = xc.out_tables(net)
    if fanout == "compact":
        tbl_args += (jnp.asarray(edge_np),)                # [N, MO], sent. E
        tbl_specs += (P(None, None),)
    if incremental:
        tbl_args += (jnp.asarray(post_np),)                # [N, MOp], sent. N
        tbl_specs += (P(None, None),)
        sf_len = int(np.prod(tp.example_args[1].shape))    # n_shards * F
        mcap = min(sf_len, n_shards * cap) if move_cap <= 0 \
            else min(int(move_cap), sf_len)

    def _insert_byk(eq_t, eq_a, eq_g, t_ev, wa, wg, valid):
        """Grouped insert over the by-post edge layout (k_in per neuron);
        row index is the (shard-relative) target neuron.  Only used on the
        shard-local path, which already constructs post_rel as
        repeat(arange, k_in) — i.e. the layout is guaranteed there."""
        k = spec.k_in
        eq = qops.wrap(eq_t, eq_a, eq_g, jnp.zeros((), jnp.int32))
        eq = qops.insert_grouped(eq, t_ev.reshape(-1, k), wa.reshape(-1, k),
                                 wg.reshape(-1, k), valid.reshape(-1, k))
        return eq

    def _round_local(sts, eq_t, eq_a, eq_g, pre_l, delay_l, wa_l, wg_l, iinj,
                     *rest):
        """One scheduler round on this shard's neurons.  All arrays are
        shard-local (the static tables replicated); the ONLY communication
        is the transport's channels (plus the scalar telemetry psums)."""
        from repro.kernels.event_wheel import ops as ew_ops

        n_carry = 3 if incremental else 0
        carry, rest = rest[:n_carry], rest[n_carry:]
        targs, tbls = rest[:n_targs], rest[n_targs:]
        t_local = sts.t
        n_loc = t_local.shape[0]
        sidx = shard_index(mesh, flat)
        offset = sidx * n_local
        # --- notify: clock exchange (stepping notifications) --------------
        t_table, bnd_new = tp.notify(t_local, *targs)
        # --- horizon + runnable (shared helper, shard-relative post) ------
        post_rel = jnp.repeat(jnp.arange(n_loc), spec.k_in)
        dloc = xc.DeviceNet(pre_l, post_rel, delay_l, wa_l, wg_l)

        def _full_horizon(_):
            return xc.horizon_times(dloc, n_loc, t_local, spec.t_end,
                                    t_table=t_table,
                                    horizon_cap=spec.horizon_cap)

        if incremental:
            hor_c, prev_bnd, moved_prev = carry
            post_tbl_r = tbls[-1]
            b_gid_flat = targs[1].reshape(-1)              # [n_shards * F]
            sf = b_gid_flat.shape[0]
            pre_byk = pre_l.reshape(n_loc, spec.k_in).T    # [K, n_loc]
            delay_byk = delay_l.reshape(n_loc, spec.k_in).T

            def _rows(p):
                """Recompute horizon rows ``p`` (sentinel-padded) from the
                fresh notify table — the same min/clamp chain as the full
                scatter-min (min is exact: incremental == full, bitwise)."""
                pc = jnp.minimum(p, n_loc - 1)
                cand = t_table[pre_byk[:, pc]] + delay_byk[:, pc]
                h = jnp.minimum(jnp.min(cand, axis=0), spec.t_end)
                return jnp.minimum(h, t_local[pc] + spec.horizon_cap)

            # moved set: frontier clocks that changed since last round
            # (pad slots carry the gid sentinel n -> masked out) plus last
            # round's locally advanced lanes (carried compact ids)
            moved_b = jnp.logical_and(bnd_new != prev_bnd, b_gid_flat < n)
            mids, mcnt = ew_ops.compact_ids(moved_b, mcap)
            gids = jnp.where(mids < sf,
                             b_gid_flat[jnp.minimum(mids, sf - 1)], n)

            def _incr_horizon(hor):
                own = jnp.where(moved_prev < n_loc, moved_prev + offset, n)
                srcs = jnp.concatenate([gids, own])
                posts = jnp.where((srcs < n)[:, None],
                                  post_tbl_r[jnp.minimum(srcs, n - 1)], n)
                p_loc = posts - offset
                p_loc = jnp.where(
                    jnp.logical_and(p_loc >= 0, p_loc < n_loc), p_loc, n_loc)
                p = jnp.concatenate([moved_prev, p_loc.reshape(-1)])
                return hor.at[p].set(_rows(p), mode="drop")

            horizon = jax.lax.cond(mcnt <= mcap, _incr_horizon,
                                   _full_horizon, hor_c)
            prev_bnd = bnd_new
        else:
            horizon = _full_horizon(None)
        runnable = xc.runnable_mask(t_local, horizon)
        # --- advance (dense: all lanes; compact: the shard-local active
        # set, gathered into a fixed [cap] batch and scattered back) -------
        if batch == "compact":
            ids, _ = xc.compact_frontier(runnable, t_local, cap)
            lane_ok = ids < n_loc
            idc = jnp.minimum(ids, n_loc - 1)
            sts_b = xc.gather_lanes(sts, idc)
            t_b_prev = sts_b.t
            sts_b, eqt_b, spiked_b, tsp_b, nd, nrs = vadvance(
                sts_b, eq_t[idc], eq_a[idc], eq_g[idc], horizon[idc],
                lane_ok, iinj[idc])
            sts = xc.scatter_lanes(sts, sts_b, ids)
            eq_t = xc.scatter_at(eq_t, ids, eqt_b)
            spiked = xc.scatter_at(jnp.zeros((n_loc,), bool), ids, spiked_b)
            t_sp = xc.scatter_at(jnp.zeros((n_loc,)), ids, tsp_b)
            if incremental:
                moved = jnp.logical_and(lane_ok, sts_b.t != t_b_prev)
                moved_prev = jnp.where(moved, ids, n_loc).astype(jnp.int32)
        else:
            sts, eq_t, spiked, t_sp, nd, nrs = vadvance(
                sts, eq_t, eq_a, eq_g, horizon, runnable, iinj)
        # --- parcel exchange ----------------------------------------------
        spiked_all, tsp_all, pdrop, pbytes = tp.exchange(spiked, t_sp, *targs)

        # --- insert (shard-local): dense = grouped scan of all E/n_shards
        # in-edges; compact = gather only the spiking set's out-edges that
        # land in this shard's contiguous global-edge-id slice ------------
        def _ins_dense(eq_t, eq_a, eq_g):
            valid = spiked_all[pre_l]
            t_ev = tsp_all[pre_l] + delay_l
            return _insert_byk(eq_t, eq_a, eq_g, t_ev, wa_l, wg_l, valid)

        if fanout == "compact":
            edge_tbl_r = tbls[0]

            def _ins_compact(eq_t, eq_a, eq_g):
                ids_s, eids, _ = ew_ops.compact_gather(
                    spiked_all, edge_tbl_r, s_cap, fill=E)
                idc_s = jnp.minimum(ids_s, n - 1)
                le = eids - sidx * e_local
                ok = jnp.logical_and(
                    (ids_s < n)[:, None],
                    jnp.logical_and(eids < E,
                                    jnp.logical_and(le >= 0, le < e_local)))
                lec = jnp.clip(le, 0, e_local - 1)
                tgt = lec // spec.k_in          # shard-relative post (grouped)
                t_ev = tsp_all[idc_s][:, None] + delay_l[lec]
                eq = qops.wrap(eq_t, eq_a, eq_g, jnp.zeros((), jnp.int32))
                return qops.insert_batch(
                    eq, tgt.ravel(), t_ev.ravel(), wa_l[lec].ravel(),
                    wg_l[lec].ravel(), ok.ravel())

            eq = jax.lax.cond(spiked_all.sum() <= s_cap, _ins_compact,
                              _ins_dense, eq_t, eq_a, eq_g)
        else:
            eq = _ins_dense(eq_t, eq_a, eq_g)
        nd = jax.lax.psum(nd.sum(), flat)
        nrs = jax.lax.psum(nrs.sum(), flat)
        dropped = jax.lax.psum(eq.dropped + pdrop, flat)
        out = (sts, eq.t, eq.w_ampa, eq.w_gaba, spiked, t_sp, nd, nrs,
               dropped, pbytes)
        if incremental:
            out += (horizon, prev_bnd, moved_prev)
        return out

    # carried-extra specs: horizon [N] sharded, boundary-clock vector
    # replicated (the all_gather output IS replicated), moved ids [S*cap]
    # sharded (each shard's own compact batch)
    carry_specs = (P(flat), P(None), P(flat)) if incremental else ()

    def fap_round(sts, eq_t, eq_a, eq_g, pre, post, delay, w_a, w_g, iinj,
                  *rest):
        if optimized:
            # per-leaf specs: leading neuron dim sharded over every axis
            sts_specs = jax.tree_util.tree_map(
                lambda leaf: P(flat, *([None] * (leaf.ndim - 1))), sts)
            n2 = P(flat, None)
            fn_l = shard_map(
                _round_local, mesh=mesh,
                in_specs=(sts_specs, n2, n2, n2, P(flat), P(flat), P(flat),
                          P(flat), P(flat)) + carry_specs + tp.in_specs
                + tbl_specs,
                out_specs=(sts_specs, n2, n2, n2, P(flat), P(flat), P(), P(),
                           P(), P()) + carry_specs,
                check_rep=False)
            return fn_l(sts, eq_t, eq_a, eq_g, pre, delay, w_a, w_g, iinj,
                        *rest)
        t_clock = sts.t
        dnet = xc.DeviceNet(pre, post, delay, w_a, w_g)
        horizon = xc.horizon_times(dnet, n, t_clock, spec.t_end,
                                   horizon_cap=spec.horizon_cap)
        runnable = xc.runnable_mask(t_clock, horizon)
        sts, eq_t, spiked, t_sp, nd, nrs = vadvance(
            sts, eq_t, eq_a, eq_g, horizon, runnable, iinj)
        valid = spiked[pre]
        t_ev = t_sp[pre] + delay
        # the global path honours the runtime `post` array (arbitrary edge
        # order); both queue impls insert to explicit targets sort-free or
        # not per their contract
        eq = qops.wrap(eq_t, eq_a, eq_g, jnp.zeros((), jnp.int32))
        eq = qops.insert(eq, post, t_ev, w_a, w_g, valid)
        return (sts, eq.t, eq.w_ampa, eq.w_gaba, spiked, t_sp, nd.sum(),
                nrs.sum(), eq.dropped, jnp.zeros((), jnp.int32))

    # ---- example args (ShapeDtypeStructs) and shardings -------------------
    f8 = jnp.float64
    sts = jax.eval_shape(
        lambda: jax.vmap(lambda i: bdf.reinit(
            model, 0.0, model.init_state(), i, opts))(jnp.zeros((n,), f8)))
    args = (
        sts,
        jax.ShapeDtypeStruct((n, qcap), f8),           # eq_t
        jax.ShapeDtypeStruct((n, qcap), f8),           # eq_a
        jax.ShapeDtypeStruct((n, qcap), f8),           # eq_g
        jax.ShapeDtypeStruct((E,), jnp.int32),         # pre
        jax.ShapeDtypeStruct((E,), jnp.int32),         # post
        jax.ShapeDtypeStruct((E,), f8),                # delay
        jax.ShapeDtypeStruct((E,), f8),                # w_ampa
        jax.ShapeDtypeStruct((E,), f8),                # w_gaba
        jax.ShapeDtypeStruct((n,), f8),                # iinj
    )
    carry_args = ()
    if incremental:
        carry_args = (
            jax.ShapeDtypeStruct((n,), f8),                      # horizon
            jax.ShapeDtypeStruct((sf_len,), f8),                 # prev_bnd
            jax.ShapeDtypeStruct((n_shards * cap,), jnp.int32),  # moved ids
        )
    args = args + carry_args + \
        (tp.example_args if tp is not None else ()) + tbl_args

    def st_spec(leaf):
        return NamedSharding(mesh, P(flat, *([None] * (leaf.ndim - 1))))

    sts_sh = jax.tree_util.tree_map(
        lambda leaf: st_spec(leaf) if leaf.ndim >= 1 else NamedSharding(mesh, P()),
        sts)
    esh = NamedSharding(mesh, nshard)
    n2 = NamedSharding(mesh, P(flat, None))
    carry_sh = tuple(NamedSharding(mesh, s) for s in carry_specs)
    in_shardings = (sts_sh, n2, n2, n2, esh, esh, esh, esh, esh,
                    NamedSharding(mesh, nshard)) + carry_sh + \
        (tp.shardings if tp is not None else ()) + \
        tuple(NamedSharding(mesh, s) for s in tbl_specs)
    return fap_round, args, in_shardings


def run_fap_spmd(model: CellModel, net, iinj, t_end: float, mesh,
                 opts: bdf.BDFOptions = bdf.BDFOptions(),
                 optimized: bool = True, queue: str = "dense",
                 wheel: sched.WheelSpec = sched.WheelSpec(),
                 transport: str = "allgather",
                 exchange: ExchangeSpec = ExchangeSpec(),
                 ev_cap: int = 32, horizon_cap: float = 2.0,
                 max_rounds: int = 400, spk_cap: int = 128,
                 placement=None, batch: str = "dense", batch_cap: int = 0,
                 fanout: str = "dense", spike_cap: int = 0,
                 horizon: str = "full", move_cap: int = 0,
                 checkpoint_every: int = 0, ckpt_dir=None,
                 resume: bool = False, fault=None, watchdog: bool = True,
                 max_rollbacks: int = 2, ckpt_keep: int = 3):
    """Drive the SPMD round to t_end on a concrete network; the host loop
    records spike trains and accumulates the per-round telemetry into the
    standard ``RunResult`` (dropped = queue + parcel overflow — detected,
    never silent).  Returns (RunResult, rounds).

    placement: optional ``distributed.placement.Placement`` (or a method
    name for ``compute_placement``) — the neuron-id relabeling is applied
    before sharding and inverted on the returned spike record / final
    state, so results stay in the caller's neuron order while the notify
    frontier and parcel routing shrink with the realized locality.
    Checkpoints store the *placed* order: resume with the same placement.

    batch / batch_cap / fanout / spike_cap / horizon / move_cap: forwarded
    to ``build_fap_round`` — "compact" runs the shard-local advance
    (delivery) on the compacted runnable (spiking) set only, and
    horizon="incremental" carries the dependency horizon across rounds
    recomputing only frontier-fed rows (``RunResult.sched`` telemetry is
    not collected on the SPMD path; ``RunResult.comm`` records the
    realized parcel bytes summed over rounds — with the ragged transport
    this is the per-round class choice made visible).

    Preemption tolerance (exec_common.run_checkpointed):
    checkpoint_every=k snapshots the full round state (``SimCarry``) into
    ckpt_dir every k rounds with the atomic-commit protocol; resume=True
    restarts from the latest complete checkpoint, event-for-event
    identical to the uninterrupted run.  Elastic resume onto a different
    mesh shape works transparently: only the mesh-shaped horizon-carry
    leaves are reseeded (a full recompute the incremental scheme equals
    bitwise), everything else restores through
    ``restore_checkpoint(shardings=)``.  fault: a
    ``checkpoint.FaultPlan`` for kill/poison injection; watchdog (on by
    default here) runs the per-round ``exec_common.health_check`` and
    quarantine-and-rollback on non-finite state (bounded by
    max_rollbacks, then ``RunResult.failed``); telemetry lands on
    ``RunResult.health``.
    """
    from repro.core import events as ev
    from repro.core.exec_bsp import RunResult
    from repro.distributed import placement as plc

    pl = None
    if placement is not None:
        n_shards = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        pl = placement if isinstance(placement, plc.Placement) else \
            plc.compute_placement(net, n_shards, method=placement)
        net, iinj = plc.place_inputs(net, iinj, pl)
    n = int(net.n)
    k = sched.grouped_k(net)
    if k is None:
        raise ValueError("run_fap_spmd needs make_network's grouped by-post "
                         "edge layout")
    spec = PaperNeuroSpec(n_neurons=n, k_in=k, ev_cap=ev_cap, t_end=t_end,
                          horizon_cap=horizon_cap)
    fn, ex_args, in_sh = build_fap_round(model, spec, mesh, opts,
                                         optimized=optimized, queue=queue,
                                         wheel=wheel, transport=transport,
                                         exchange=exchange, net=net,
                                         batch=batch, batch_cap=batch_cap,
                                         fanout=fanout, spike_cap=spike_cap,
                                         horizon=horizon, move_cap=move_cap)
    qops = sched.get_queue_ops(queue, ev_cap=ev_cap, wheel=wheel)
    iinj_v = jnp.broadcast_to(jnp.asarray(iinj, jnp.float64), (n,))
    dnet = xc.to_device(net)
    n_carry = 3 if horizon == "incremental" else 0
    # round-invariant args placed once with the build's shardings (the loop
    # then pays the two transport channels only, no per-round resharding)
    static = jax.device_put(
        (dnet.pre, dnet.post, dnet.delay, dnet.w_ampa, dnet.w_gaba, iinj_v)
        + ex_args[10 + n_carry:],
        in_sh[4:10] + in_sh[10 + n_carry:])

    def seed_hcarry(clocks):
        """(horizon, prev boundary clocks, moved ids) seeded from a clock
        vector: a full-recompute horizon (which the incremental chain
        equals bitwise — min is exact), all-zero previous boundary clocks
        (every frontier entry looks moved next round -> extra or full
        recompute, still exact) and sentinel moved ids.  Round 0 and
        elastic resume share this."""
        hor0 = xc.horizon_times(dnet, n, clocks, t_end,
                                horizon_cap=horizon_cap)
        prev0 = jnp.zeros(ex_args[11].shape, jnp.float64)  # boundary clocks
        moved0 = jnp.full(ex_args[12].shape, n // int(np.prod(
            [mesh.shape[a] for a in mesh.axis_names])), jnp.int32)
        return tuple(jax.device_put((hor0, prev0, moved0), in_sh[10:13]))

    jfn = jax.jit(fn, in_shardings=in_sh)
    neuron_ids = jnp.arange(n, dtype=jnp.int32)    # hoisted round constant
    repl = NamedSharding(mesh, P())
    z64 = jnp.zeros((), jnp.int64)

    def init_fn():
        Y = xc.batch_init(model, n)
        sts = jax.vmap(lambda y, i: bdf.reinit(model, 0.0, y, i, opts))(
            Y, iinj_v)
        eq = qops.make(n)
        hcarry = seed_hcarry(jnp.zeros((n,), jnp.float64)) if n_carry else ()
        rec = ev.make_spike_record(n, spk_cap)
        return xc.SimCarry(sts, (eq.t, eq.w_ampa, eq.w_gaba), rec, hcarry,
                           {"n_ev": z64, "n_rs": z64, "dropped": z64,
                            "parcel_bytes": z64,
                            "rounds": jnp.zeros((), jnp.int32)})

    def step_fn(sc):
        eq_t, eq_a, eq_g = sc.eq
        out = jfn(sc.sts, eq_t, eq_a, eq_g, *static[:6], *sc.hcarry,
                  *static[6:])
        (sts, eq_t, eq_a, eq_g, spiked, t_sp, nd, nrs, dropped,
         pbytes) = out[:10]
        rec = ev.record_spikes(sc.rec, neuron_ids, t_sp, spiked)
        c = sc.counters
        return xc.SimCarry(sts, (eq_t, eq_a, eq_g), rec, out[10:], {
            "n_ev": c["n_ev"] + nd, "n_rs": c["n_rs"] + nrs,
            "dropped": c["dropped"] + dropped,
            "parcel_bytes": c["parcel_bytes"] + pbytes,
            "rounds": c["rounds"] + 1})

    def cond_fn(sc):
        return (int(sc.counters["rounds"]) < max_rounds
                and float(sc.sts.t.min()) < t_end - 1e-9
                and not bool(sc.sts.failed.any()))

    # SimCarry-shaped sharding tree for restore: the build's input
    # shardings where they exist, replicated for the host-side leaves
    # (spike record + counters) — the elastic-resume device_put path
    sh_tree = xc.SimCarry(
        in_sh[0], in_sh[1:4],
        jax.tree_util.tree_map(lambda _: repl, ev.make_spike_record(1, 1)),
        tuple(in_sh[10:13]) if n_carry else (),
        {k_: repl for k_ in ("n_ev", "n_rs", "dropped", "parcel_bytes",
                             "rounds")})

    def health_of(sc, t_prev):
        return xc.health_check(
            sc.sts, t_prev, horizon=sc.hcarry[0] if n_carry else None,
            horizon_cap=horizon_cap)

    # layout fingerprint: a resume whose mesh/transport layout changed must
    # reseed the shard-relative hcarry even when its widths coincide
    fingerprint = {"mesh_shape": [int(mesh.shape[a]) for a in mesh.axis_names],
                   "transport": transport, "batch_cap": int(batch_cap),
                   "horizon": horizon} if n_carry else None
    sc, health = xc.run_checkpointed(
        init_fn, step_fn, cond_fn, ckpt_dir=ckpt_dir,
        checkpoint_every=checkpoint_every, resume=resume, keep=ckpt_keep,
        fault=fault, health_of=health_of if watchdog else None,
        max_rollbacks=max_rollbacks, shardings=sh_tree,
        fingerprint=fingerprint,
        reseed=(lambda sc: sc._replace(hcarry=seed_hcarry(sc.sts.t)))
        if n_carry else None)
    sts = sc.sts
    rounds = int(sc.counters["rounds"])
    health["dropped_events"] = int(sc.counters["dropped"])
    res = RunResult(sc.rec, sts.nst.sum(),
                    jnp.asarray(sc.counters["n_ev"], jnp.int32),
                    jnp.asarray(sc.counters["n_rs"], jnp.int32),
                    jnp.asarray(sc.counters["dropped"], jnp.int32),
                    jnp.logical_or(sts.failed.any(),
                                   health["rollback_exhausted"]),
                    sts.zn[:, 0],
                    comm={"parcel_bytes": int(sc.counters["parcel_bytes"]),
                          "rounds": rounds},
                    solver=xc.solver_stats(sts), health=health)
    if pl is not None:
        res = plc.unpermute_result(res, pl)
    return res, rounds

"""SPMD (multi-pod) realisation of the FAP variable-timestep scheduler round.

This is the paper's execution model mapped onto the production mesh
(DESIGN.md §3): neurons (and their BDF integrator states, event queues and
out-edge lists) are sharded across every mesh axis; one jitted ``fap_round``
advances all runnable neurons to their dependency horizons.

Collectives per round (inserted by GSPMD from the shardings):
  * clock exchange — gather of t[pre] along cross-shard in-edges
    (the paper's stepping notifications, amortised exactly the same way:
    one exchange per round, not per neuron pair),
  * event exchange — the argsort-based queue insert over the edge list
    (spike parcels; the §Perf hillclimb replaces the global sort with a
    per-shard bucketed exchange).

``build_fap_round`` returns (fn, example_args, in_shardings) so the dry-run
can lower it on the 16x16 and 2x16x16 meshes like any LM cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import sched
from repro.core import bdf
from repro.core.cell import CellModel
from repro.core.exec_bsp import make_vardt_advance


class PaperNeuroSpec(NamedTuple):
    n_neurons: int = 1 << 20          # 2^20 neurons (4x the paper's 219k lab run)
    k_in: int = 16
    n_comp: int = 29                  # branched_tree(depth=3)
    ev_cap: int = 32
    t_end: float = 1000.0
    horizon_cap: float = 2.0


def build_fap_round(model: CellModel, spec: PaperNeuroSpec, mesh,
                    opts: bdf.BDFOptions = bdf.BDFOptions(),
                    optimized: bool = False, queue: str = "dense",
                    wheel: sched.WheelSpec = sched.WheelSpec()):
    """optimized=False: paper-faithful baseline — horizon scatter-min and
    event insert as *global* ops, lowered by GSPMD (collective-heavy: with
    queue="dense" the global argsort in the insert becomes a distributed
    sort; queue="wheel" already removes the sort from the global path).

    optimized=True (§Perf): the communication is exactly the paper's two
    notification channels and nothing else —
      (1) one all-gather of the neuron clock vector (stepping notifications),
      (2) one all-gather of (spiked, t_spike) (spike parcels),
    after which horizon computation and queue insertion run SHARD-LOCAL
    inside shard_map (edges are sharded by postsynaptic neuron, aligned
    with the neuron sharding, so no event ever crosses shards again).
    With queue="wheel" the shard-local insert is the bucketed event-wheel
    scatter (repro.sched) — no sort of any kind, local or distributed.
    """
    from functools import partial

    from jax.experimental.shard_map import shard_map

    n, E = spec.n_neurons, spec.n_neurons * spec.k_in
    flat = tuple(mesh.axis_names)                  # shard over ALL axes
    nshard = P(flat)
    advance = make_vardt_advance(model, opts, eg_window=0.0, step_budget=8)
    vadvance = jax.vmap(advance)
    n_shards = int(np.prod([mesh.shape[a] for a in flat]))
    n_local = n // n_shards
    qops = sched.get_queue_ops(queue, ev_cap=spec.ev_cap, wheel=wheel)
    qcap = qops.capacity

    def _insert_byk(eq_t, eq_a, eq_g, t_ev, wa, wg, valid):
        """Grouped insert over the by-post edge layout (k_in per neuron);
        row index is the (shard-relative) target neuron.  Only used on the
        shard-local path, which already constructs post_rel as
        repeat(arange, k_in) — i.e. the layout is guaranteed there."""
        k = spec.k_in
        eq = qops.wrap(eq_t, eq_a, eq_g, jnp.zeros((), jnp.int32))
        eq = qops.insert_grouped(eq, t_ev.reshape(-1, k), wa.reshape(-1, k),
                                 wg.reshape(-1, k), valid.reshape(-1, k))
        return eq

    def _gather_axes(x):
        for ax in reversed(flat):
            x = jax.lax.all_gather(x, ax, tiled=True)
        return x

    def _shard_offset():
        idx = jnp.zeros((), jnp.int32)
        for ax in flat:
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        return idx * n_local

    def _round_local(sts, eq_t, eq_a, eq_g, pre_l, delay_l, wa_l, wg_l, iinj):
        """One scheduler round on this shard's neurons.  All arrays are
        shard-local; the ONLY communication is two explicit all-gathers —
        the paper's clock-notification and spike-parcel channels."""
        t_clock = sts.t
        t_all = _gather_axes(t_clock)                  # (1) notifications
        cand = t_all[pre_l] + delay_l
        post_rel = jnp.repeat(jnp.arange(t_clock.shape[0]), spec.k_in)
        horizon = jnp.full(t_clock.shape, spec.t_end, t_clock.dtype)
        horizon = horizon.at[post_rel].min(cand)
        horizon = jnp.minimum(horizon, t_clock + spec.horizon_cap)
        runnable = t_clock < horizon - 1e-12
        sts, eq_t, spiked, t_sp, nd, nrs = vadvance(
            sts, eq_t, eq_a, eq_g, horizon, runnable, iinj)
        spiked_all = _gather_axes(spiked)              # (2) spike parcels
        tsp_all = _gather_axes(t_sp)
        valid = spiked_all[pre_l]
        t_ev = tsp_all[pre_l] + delay_l
        eq = _insert_byk(eq_t, eq_a, eq_g, t_ev, wa_l, wg_l, valid)
        nd = jax.lax.psum(nd.sum(), flat)
        nrs = jax.lax.psum(nrs.sum(), flat)
        return sts, eq.t, eq.w_ampa, eq.w_gaba, spiked, nd, nrs

    def fap_round(sts, eq_t, eq_a, eq_g, pre, post, delay, w_a, w_g, iinj):
        if optimized:
            # per-leaf specs: leading neuron dim sharded over every axis
            sts_specs = jax.tree_util.tree_map(
                lambda leaf: P(flat, *([None] * (leaf.ndim - 1))), sts)
            n2 = P(flat, None)
            fn_l = shard_map(
                _round_local, mesh=mesh,
                in_specs=(sts_specs, n2, n2, n2, P(flat), P(flat), P(flat),
                          P(flat), P(flat)),
                out_specs=(sts_specs, n2, n2, n2, P(flat), P(), P()),
                check_rep=False)
            return fn_l(sts, eq_t, eq_a, eq_g, pre, delay, w_a, w_g, iinj)
        t_clock = sts.t
        cand = t_clock[pre] + delay
        horizon = jnp.full((n,), spec.t_end, t_clock.dtype).at[post].min(cand)
        horizon = jnp.minimum(horizon, t_clock + spec.horizon_cap)
        runnable = t_clock < horizon - 1e-12
        sts, eq_t, spiked, t_sp, nd, nrs = vadvance(
            sts, eq_t, eq_a, eq_g, horizon, runnable, iinj)
        valid = spiked[pre]
        t_ev = t_sp[pre] + delay
        # the global path honours the runtime `post` array (arbitrary edge
        # order); both queue impls insert to explicit targets sort-free or
        # not per their contract
        eq = qops.wrap(eq_t, eq_a, eq_g, jnp.zeros((), jnp.int32))
        eq = qops.insert(eq, post, t_ev, w_a, w_g, valid)
        return sts, eq.t, eq.w_ampa, eq.w_gaba, spiked, nd.sum(), nrs.sum()

    # ---- example args (ShapeDtypeStructs) and shardings -------------------
    f8 = jnp.float64
    sts = jax.eval_shape(
        lambda: jax.vmap(lambda i: bdf.reinit(
            model, 0.0, model.init_state(), i, opts))(jnp.zeros((n,), f8)))
    args = (
        sts,
        jax.ShapeDtypeStruct((n, qcap), f8),           # eq_t
        jax.ShapeDtypeStruct((n, qcap), f8),           # eq_a
        jax.ShapeDtypeStruct((n, qcap), f8),           # eq_g
        jax.ShapeDtypeStruct((E,), jnp.int32),         # pre
        jax.ShapeDtypeStruct((E,), jnp.int32),         # post
        jax.ShapeDtypeStruct((E,), f8),                # delay
        jax.ShapeDtypeStruct((E,), f8),                # w_ampa
        jax.ShapeDtypeStruct((E,), f8),                # w_gaba
        jax.ShapeDtypeStruct((n,), f8),                # iinj
    )

    def st_spec(leaf):
        return NamedSharding(mesh, P(flat, *([None] * (leaf.ndim - 1))))

    sts_sh = jax.tree_util.tree_map(
        lambda leaf: st_spec(leaf) if leaf.ndim >= 1 else NamedSharding(mesh, P()),
        sts)
    esh = NamedSharding(mesh, nshard)
    n2 = NamedSharding(mesh, P(flat, None))
    in_shardings = (sts_sh, n2, n2, n2, esh, esh, esh, esh, esh,
                    NamedSharding(mesh, nshard))
    return fap_round, args, in_shardings

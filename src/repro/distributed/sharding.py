"""Named-sharding rules for every parameter / activation in the framework.

Scheme (DESIGN.md §6):
  * TP  — attention/FFN output features, MoE expert dim, vocab over ``model``
  * FSDP — the complementary feature dim over ('pod','data') (ZeRO-3;
    optimizer state shards identically)
  * SP  — residual-stream sequence dim over ``model`` between layers
  * batch — over ('pod','data')
  * decode KV caches — sequence dim over ``model`` (kv-head counts are not
    generally divisible by the TP degree; sequence always is)

Rules are name-based over the parameter tree; anything unmatched replicates
(and is asserted to be small).  jax.jit tolerates non-divisible dims by
padding, so e.g. vocab=151655 shards fine over 16.

The neuro workload's static routing tables (``shard_frontier``) also live
here: the sparse spike-parcel transport (``distributed.exchange``) consumes
per-shard boundary sets and destination maps derived host-side from the
by-post edge layout.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig

# parameter-name classes
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "cm_k", "w_in", "w1",
        "w_lora_a", "wr", "wg", "cm_r"}          # [d_in, d_out]: shard d_out
_ROW = {"wo", "w_down", "cm_v", "w2", "w_out", "w_lora_b", "wv_rwkv"}
_EMB = {"emb", "enc_pos"}
_MOE_COL = {"w_gate", "w_up"}                     # under "moe": [E, d, ff]
_MOE_ROW = {"w_down"}                             # under "moe": [E, ff, d]
_REPL_SMALL = {"ln1", "ln2", "ln", "lnx", "ln_f", "ln_x", "ln_y", "b1", "b2",
               "bx", "bn_f", "bq", "bk", "bv", "conv_b", "A_log", "D",
               "dt_bias", "w_bias", "bonus_u", "mu_r", "mu_k", "mu_v", "mu_w",
               "mu_g", "mu_ck", "mu_cr", "router", "conv_w", "patch_proj"}


def mesh_axes(mesh) -> Tuple[tuple, str]:
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return dp, "model"


def _leaf_spec(path, leaf, dp, tp):
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    in_moe = "moe" in names
    stacked = any(n in ("layers", "dec", "enc") for n in names)
    nd = leaf.ndim
    pre = (None,) if stacked else ()

    def spec(*dims):
        full = pre + dims
        if len(full) != nd:
            return P()                            # fallback: replicate
        return P(*full)

    if in_moe and name in _MOE_COL:
        return spec(tp, dp, None)
    if in_moe and name in _MOE_ROW:
        return spec(tp, None, dp)
    if name == "router":
        return spec(dp, None)
    if name in _EMB:
        return P(tp, dp)
    if name in _COL:
        return spec(dp, tp)
    if name in _ROW:
        return spec(tp, dp)
    if name in ("wq", "wk", "wv", "wo"):
        return spec(dp, tp)
    return P()                                     # norms, biases, scalars


def param_specs(cfg: ArchConfig, params_tree, mesh):
    """PartitionSpec tree matching a params (or grads/adam-moment) tree.

    ``params_tree`` may be real arrays or ShapeDtypeStructs.
    """
    dp, tp = mesh_axes(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, dp, tp), params_tree)


def opt_specs(cfg: ArchConfig, opt_state, mesh):
    """AdamWState(step, mu, nu): moments shard like params."""
    from repro.optim import AdamWState
    return AdamWState(step=P(),
                      mu=param_specs(cfg, opt_state.mu, mesh),
                      nu=param_specs(cfg, opt_state.nu, mesh))


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Input shardings for a train/prefill batch dict."""
    dp, tp = mesh_axes(mesh)
    specs = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.family == "audio":
        specs["frames"] = P(dp, None, None)
    if cfg.family == "vlm":
        specs["patches"] = P(dp, None, None)
    return specs


def decode_state_specs(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Shardings for the decode cache / recurrent state."""
    dp, tp = mesh_axes(mesh)
    ndp = int(np.prod([mesh.shape[a] for a in dp]))
    bdim = dp if shape.global_batch >= ndp else None   # tiny batches replicate
    if cfg.family in ("dense", "vlm", "moe"):
        # [L, B, S, Hkv, hd]: sequence over model (SP decode)
        kv = P(None, bdim, tp, None, None)
        return {"k": kv, "v": kv}
    if cfg.family == "ssm":
        return {
            "tm_x": P(None, bdim, tp),
            "cm_x": P(None, bdim, tp),
            "S": P(None, bdim, tp, None, None),
        }
    if cfg.family == "hybrid":
        kv = P(None, bdim, tp, None, None)
        return {
            "conv": P(None, bdim, None, tp),
            "h": P(None, bdim, tp, None, None),
            "k": kv, "v": kv,
        }
    if cfg.family == "audio":
        kv = P(None, bdim, tp, None, None)
        return {"k": kv, "v": kv,
                "ek": P(None, bdim, None, None, None),
                "ev": P(None, bdim, None, None, None)}
    raise ValueError(cfg.family)


def token_spec(cfg: ArchConfig, shape: ShapeConfig, mesh):
    dp, _ = mesh_axes(mesh)
    ndp = int(np.prod([mesh.shape[a] for a in dp]))
    bdim = dp if shape.global_batch >= ndp else None
    return P(bdim, None)


def _fit_one(spec: P, shape, mesh) -> P:
    """Drop axis assignments whose size does not divide the dim (jit
    in_shardings require exact divisibility; e.g. vocab=151655 vs 16)."""
    if len(spec) > len(shape):
        return P()
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for a in axes:
            if dim % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def fit_specs(spec_tree, sds_tree, mesh):
    """Apply _fit_one leafwise: spec_tree parallel to sds_tree."""
    return jax.tree_util.tree_map(
        lambda s, leaf: _fit_one(s, leaf.shape, mesh),
        spec_tree, sds_tree,
        is_leaf=lambda x: isinstance(x, P))


def to_named(tree_of_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# neuro workload: static shard-frontier routing tables (sparse transport)
# ---------------------------------------------------------------------------
class ShardFrontier(NamedTuple):
    """Static routing tables for the sparse exchange, one row per shard.

    boundary_rel: i32[n_shards, F] — shard-relative indices of each shard's
        boundary neurons (local neurons with at least one cross-shard
        out-edge); pad slots hold 0 and are neutralised by the gid sentinel.
    boundary_gid: i32[n_shards, F] — the same neurons as global ids; pad
        slots hold the sentinel ``n`` (parked out of range on scatter).
    dest_map: bool[N, n_shards] — dest_map[i, d] iff neuron i has at least
        one out-edge into shard d (self-shard included, so parcels
        self-deliver through the same path).
    """
    boundary_rel: np.ndarray
    boundary_gid: np.ndarray
    dest_map: np.ndarray

    @property
    def frontier_size(self) -> int:
        return int(self.boundary_rel.shape[1])

    @property
    def sizes(self) -> np.ndarray:
        """True per-shard boundary counts (pad sentinels excluded)."""
        n = int(self.dest_map.shape[0])
        return (self.boundary_gid < n).sum(axis=1)


def shard_frontier(pre: np.ndarray, post: np.ndarray, n: int,
                   n_shards: int, perm: np.ndarray = None) -> ShardFrontier:
    """Derive the per-shard cross-shard in-edge frontier from the edge list.

    Neurons are block-sharded (shard of gid g = g // n_local, matching the
    round's ``P(flat)`` row sharding); edges are read host-side once at
    build time, so the returned tables are static for the whole run.

    ``perm`` (optional, from ``distributed.placement``): a neuron-id
    relabeling applied to both endpoints before deriving the tables — the
    frontier, and hence the sparse transport's notify bytes, then shrink
    with whatever locality the placement realizes.  The returned tables
    are in *new* (placed) ids, matching a run on the placed network.
    """
    if n % n_shards:
        raise ValueError(f"n={n} not divisible by n_shards={n_shards}")
    n_local = n // n_shards
    pre = np.asarray(pre, np.int64)
    post = np.asarray(post, np.int64)
    if perm is not None:
        perm = np.asarray(perm, np.int64)
        pre, post = perm[pre], perm[post]
    src_shard = pre // n_local
    dst_shard = post // n_local

    # destination map: unique (pre, dst_shard) pairs
    dest_map = np.zeros((n, n_shards), bool)
    dest_map[pre, dst_shard] = True

    # boundary set of shard s: its neurons appearing as pre on cross edges
    cross = src_shard != dst_shard
    bnd = np.unique(np.stack([src_shard[cross], pre[cross]], axis=1), axis=0)
    sizes = np.bincount(bnd[:, 0], minlength=n_shards) if bnd.size else \
        np.zeros(n_shards, np.int64)
    F = max(1, int(sizes.max()) if sizes.size else 1)
    boundary_gid = np.full((n_shards, F), n, np.int32)
    boundary_rel = np.zeros((n_shards, F), np.int32)
    for s in range(n_shards):
        gids = bnd[bnd[:, 0] == s, 1]
        boundary_gid[s, : len(gids)] = gids
        boundary_rel[s, : len(gids)] = gids - s * n_local
    return ShardFrontier(boundary_rel, boundary_gid, dest_map)

"""Lane <-> request bookkeeping for the multi-tenant simulation service.

``repro.serve`` batches T independent experiment replays over a vmapped
tenant axis of ONE compiled FAP round (``run.tenant_round``): every carry
leaf gains a leading [T] lane dimension and each lane is an isolated
network realization (shared topology *shape*, per-tenant stimulus).  The
helpers here are the pure pytree plumbing that keeps that isolation
honest:

  * ``stack_lanes`` / ``lane_slice`` / ``write_lane`` — restructure
    between the batched carry and a single tenant's carry without ever
    mixing lanes (``write_lane`` touches exactly one index of every
    leaf),
  * ``TenantRequest`` / ``TenantResult`` — the service's wire types: a
    submitted experiment and its single terminal outcome (exactly one of
    completed / evicted / rejected — the detected-never-silent
    accounting the property tests assert),
  * ``LaneState`` — the host-side per-lane state machine record
    (admission round, retry/backoff bookkeeping, the in-memory
    round-boundary snapshot quarantine rolls back to).

Because lanes are independent under ``jax.vmap`` and an inactive lane's
round is a semantic no-op on its state, a tenant's trajectory depends
only on its own carry and knobs — not on which neighbours happen to be
admitted, quarantined or shed.  That is the invariant behind the
solo-vs-batch bitwise-identity tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


# --- batched-carry plumbing -------------------------------------------------

def stack_lanes(carries):
    """Stack per-tenant carry pytrees along a new leading lane axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)


def lane_slice(batched, k: int):
    """One tenant's carry: index every leaf's leading lane axis at ``k``.

    The slice is a fresh pytree of (immutable) arrays — holding it as a
    quarantine snapshot is safe without copying."""
    return jax.tree_util.tree_map(lambda x: x[k], batched)


def write_lane(batched, k: int, lane_tree):
    """Write one tenant's carry back into lane ``k`` of the batch.

    Touches exactly index ``k`` of every leaf — the other lanes' values
    are preserved bitwise (admission, rollback and fault injection all
    go through here so cross-tenant perturbation is impossible by
    construction)."""
    return jax.tree_util.tree_map(lambda b, v: b.at[k].set(v),
                                  batched, lane_tree)


# --- wire types -------------------------------------------------------------

@dataclass(frozen=True)
class TenantRequest:
    """One experiment replay submitted to the service.

    rid:             caller-chosen unique request id.
    iinj:            per-tenant stimulus (scalar injected current; the
                     five-generator suite's regimes are all reachable by
                     scalar iinj over the shared topology shape).
    qos:             QoS class, higher = more important.  Admission pops
                     highest class first; overload/queue-full shedding
                     evicts lowest class first; ``SimService.qos_caps``
                     maps a class to its per-round frontier cap (the
                     traced ``k_qos`` of ``run.tenant_round`` — the
                     per-tenant realization of the batch_cap/spike_cap
                     family of knobs).
    t_target:        simulated-time goal (ms); None = the service's
                     ``t_end``.  Completion is ``min lane clock >=
                     t_target``.
    deadline_rounds: max service rounds after admission (0 = none) —
                     exceeded tenants are *evicted*, never silently kept.
    deadline_s:      wall-clock deadline after admission (0 = none).
    max_retries:     per-tenant quarantine-retry budget override
                     (None = the service backoff policy's).
    """
    rid: int
    iinj: float = 0.0
    qos: int = 1
    t_target: Optional[float] = None
    deadline_rounds: int = 0
    deadline_s: float = 0.0
    max_retries: Optional[int] = None


@dataclass
class TenantResult:
    """The single terminal outcome of a request — every submitted rid
    gets exactly one, whatever happens (completed / evicted / rejected;
    ``reason`` says why for the non-completed ones)."""
    rid: int
    status: str                      # "completed" | "evicted" | "rejected"
    reason: str = ""
    times: Optional[np.ndarray] = None    # f64[N, S] spike times (+inf pad)
    count: Optional[np.ndarray] = None    # i32[N] per-neuron spike counts
    overflow: int = 0
    rounds: int = 0                  # service rounds the tenant ran
    retries: int = 0                 # quarantine-retry attempts consumed
    wait_rounds: int = 0             # admission latency (queue wait)
    health: dict = field(default_factory=dict)


@dataclass
class LaneState:
    """Host-side record of one occupied lane (the service state machine)."""
    lane: int
    req: TenantRequest
    submit_round: int
    admit_round: int
    admit_time: float
    rounds_run: int = 0              # service rounds actually stepped
    retries: int = 0                 # quarantine-retry attempts consumed
    nonfinite_rounds: int = 0
    backoff_until: int = -1          # service round the quarantine lifts
    quarantined: bool = False
    snapshot: Any = None             # last clean lane-slice carry
    snapshot_round: int = 0          # rounds_run the snapshot was taken at

    def health(self) -> dict:
        return {"rounds": self.rounds_run, "retries": self.retries,
                "nonfinite_rounds": self.nonfinite_rounds,
                "quarantined": self.quarantined,
                "wait_rounds": self.admit_round - self.submit_round}

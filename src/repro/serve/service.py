"""Multi-tenant simulation serving: continuous admission, per-tenant
quarantine/retry, deadline/QoS enforcement and graceful overload shedding.

``SimService`` vmaps the existing compiled FAP round over a tenant axis
(``run.tenant_round`` — per-tenant stimulus, lane mask and QoS frontier
cap as traced arguments, so ONE compilation serves every tenant mix) and
drives a host-side state machine at round boundaries:

  admission   — a bounded request queue admits experiments into freed
                lanes, highest QoS class first, FIFO within a class;
                queue overflow sheds the lowest-QoS request (incoming or
                queued) with an explicit rejection — never a silent drop.
  isolation   — ``exec_common.health_check_tenants`` issues per-tenant
                verdicts each round; a non-finite tenant is quarantined
                (lane masked out of the batch), rolled back to its OWN
                last clean round-boundary snapshot and retried under
                ``ExponentialBackoff`` with bounded attempts, while every
                other tenant advances undisturbed.  A solver-failure
                latch on finite state is deterministic — retrying cannot
                help, so the tenant is evicted instead.
  deadlines   — per-tenant service-round and wall-clock deadlines evict
                overrunning tenants to the accounting, never stall the
                batch.
  shedding    — when the ``StragglerMonitor`` flags *sustained*
                round-time regression, the admission controller sheds
                one lowest-QoS queued request per round with an explicit
                "shed:overload" rejection.

Every submitted request terminates in exactly one of {completed,
evicted, rejected} — ``ServeResult.assert_accounting()`` checks the
partition and the tests/benchmarks call it.  Because lanes are
independent under vmap and inactive lanes are semantic no-ops, a
tenant's final spike train is bitwise identical whether it ran solo,
in a full batch, or through a quarantine/retry cycle — the golden
isolation property ``tests/test_serve.py`` asserts event-for-event.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (ExponentialBackoff, save_tenant_checkpoint)
from repro.checkpoint.fault_tolerance import (FaultPlan, SimulatedFailure,
                                              StragglerMonitor)
from repro.core import exec_common as xc
from repro.core.exec_fap import make_fap_vardt_runner
from repro.serve.tenants import (LaneState, TenantRequest, TenantResult,
                                 lane_slice, stack_lanes, write_lane)


@dataclass
class ServeResult:
    """Detected-never-silent accounting of one service run."""
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    evicted: int = 0
    rejected: int = 0
    retried: int = 0            # quarantine-retry attempts consumed
    quarantines: int = 0        # quarantine events (rollback + backoff)
    shed: int = 0               # rejections due to queue-full / overload
    rounds: int = 0             # service rounds driven
    results: dict = field(default_factory=dict)   # rid -> TenantResult
    health: dict = field(default_factory=dict)

    def assert_accounting(self):
        """Every submitted request has exactly one terminal state and the
        counters partition ``submitted`` — zero silent drops anywhere."""
        assert self.completed + self.evicted + self.rejected == \
            self.submitted, self
        assert len(self.results) == self.submitted, self
        by = {"completed": 0, "evicted": 0, "rejected": 0}
        for r in self.results.values():
            assert r.status in by, r
            by[r.status] += 1
        assert by["completed"] == self.completed, (by, self)
        assert by["evicted"] == self.evicted, (by, self)
        assert by["rejected"] == self.rejected, (by, self)
        assert self.shed <= self.rejected, self
        return self


class SimService:
    """Tenant-isolated simulation service over one compiled FAP round.

    lanes:        width of the vmapped tenant axis (concurrent tenants).
    queue_cap:    bound of the admission queue; overflow sheds lowest-QoS.
    backoff:      ``ExponentialBackoff`` quarantine-retry policy (delays
                  in service rounds; ``max_retries`` bounds attempts).
    qos_caps:     {qos_class: k_qos} per-round frontier caps (0/absent =
                  unlimited) — the per-tenant QoS realization of the
                  batch_cap knob, traced through ``run.tenant_round`` so
                  every class shares the one compiled round.
    straggler:    a configured ``StragglerMonitor`` (window / regression
                  threshold knobs); its ``sustained()`` verdict triggers
                  overload shedding and its ``stats()`` ride
                  ``ServeResult.health["straggler"]``.
    shed_sustained_frac: fraction of the monitor window that must flag to
                  call the regression sustained.
    ckpt_dir / checkpoint_every: optional per-tenant durability — every
                  ``checkpoint_every`` clean rounds a tenant's carry
                  slice is committed to its own atomic checkpoint
                  directory (``checkpoint.save_tenant_checkpoint``), so
                  an external restart can resume individual tenants.
    fault:        ``FaultPlan`` with ``poison_tenant`` set targets one
                  *request id*: once that tenant is admitted and has run
                  ``poison_at_round`` rounds, neuron ``poison_lane`` of
                  its OWN network is poisoned (injected once).
                  ``fail_at_round`` (service rounds) still simulates a
                  whole-service preemption.
    runner:       pass a prebuilt ``make_fap_vardt_runner`` product to
                  share one compiled round across services (the identity
                  tests compare solo vs batch under the same jaxpr).
    """

    def __init__(self, model=None, net=None, t_end: float = 10.0, *,
                 lanes: int = 4, queue_cap: int = 8,
                 backoff: ExponentialBackoff = ExponentialBackoff(),
                 qos_caps: Optional[dict] = None,
                 straggler: Optional[StragglerMonitor] = None,
                 shed_sustained_frac: float = 0.25,
                 ckpt_dir: Optional[str] = None, checkpoint_every: int = 0,
                 snapshot_every: int = 1,
                 fault: Optional[FaultPlan] = None,
                 runner=None, runner_kwargs: Optional[dict] = None,
                 log_fn=None):
        if runner is None:
            if model is None or net is None:
                raise ValueError("need model+net or a prebuilt runner=")
            runner = make_fap_vardt_runner(model, net, 0.0, t_end,
                                           **(runner_kwargs or {}))
        self.runner = runner
        self.t_end = float(t_end)
        self.L = int(lanes)
        self.queue_cap = int(queue_cap)
        self.backoff = backoff
        self.qos_caps = dict(qos_caps or {})
        self.monitor = straggler if straggler is not None \
            else StragglerMonitor()
        self.shed_sustained_frac = float(shed_sustained_frac)
        self.ckpt_dir = ckpt_dir
        self.checkpoint_every = int(checkpoint_every)
        self.snapshot_every = max(1, int(snapshot_every))
        self.fault = fault
        self._poison_pending = (fault is not None
                                and fault.poison_tenant is not None)
        self.log = log_fn or (lambda *_: None)

        # one vmapped+jitted round serves every tenant mix: stimulus, lane
        # mask and QoS cap are traced arguments, never recompile triggers.
        # Cached on the runner so every service sharing it shares ONE
        # compiled executable — the identity tests compare solo vs batch
        # under literally the same program.
        if not hasattr(runner, "serve_vround"):
            runner.serve_vround = jax.jit(
                jax.vmap(runner.tenant_round, in_axes=(0, 0, 0, 0)))
        self._vround = runner.serve_vround
        base = runner.init_carry(0.0)
        self._carry = stack_lanes([base] * self.L)

        self.lanes: list = [None] * self.L     # Optional[LaneState]
        self.queue: deque = deque()            # pending TenantRequest entries
        self.round = 0
        self.res = ServeResult()
        self._wait_rounds: list = []           # admission latencies
        self._snapshots_saved = 0

    # --- admission / shedding ---------------------------------------------

    def submit(self, req: TenantRequest) -> bool:
        """Enqueue a request; a full queue sheds the lowest-QoS request
        (the incoming one or a queued one) with an explicit rejection.
        Returns True when ``req`` is queued."""
        if req.rid in self.res.results or \
                any(r.rid == req.rid for r, _ in self.queue) or \
                any(ls is not None and ls.req.rid == req.rid
                    for ls in self.lanes):
            raise ValueError(f"duplicate request id {req.rid}")
        self.res.submitted += 1
        if len(self.queue) >= self.queue_cap:
            # shed lowest QoS; ties shed the incoming request (FIFO wins)
            victim_i = min(range(len(self.queue)),
                           key=lambda i: self.queue[i][0].qos)
            victim, _ = self.queue[victim_i]
            if victim.qos < req.qos:
                del self.queue[victim_i]
                self._reject(victim, "shed:queue_full", shed=True)
                self.queue.append((req, self.round))
                return True
            self._reject(req, "shed:queue_full", shed=True)
            return False
        self.queue.append((req, self.round))
        return True

    def _reject(self, req: TenantRequest, reason: str, shed: bool = False):
        self.res.rejected += 1
        if shed:
            self.res.shed += 1
        self.res.results[req.rid] = TenantResult(req.rid, "rejected", reason)
        self.log(f"[serve] rejected rid={req.rid} ({reason})")

    def _shed_overload(self):
        """Sustained round-time regression: shed one lowest-QoS queued
        request per round (graceful — running tenants are never killed)."""
        if not self.queue or \
                not self.monitor.sustained(self.shed_sustained_frac):
            return
        i = min(range(len(self.queue)), key=lambda i: self.queue[i][0].qos)
        req, _ = self.queue[i]
        del self.queue[i]
        self._reject(req, "shed:overload", shed=True)

    def _admit(self):
        free = [k for k in range(self.L) if self.lanes[k] is None]
        for k in free:
            if not self.queue:
                break
            # highest QoS class first, FIFO (submit order) within a class
            i = min(range(len(self.queue)),
                    key=lambda i: (-self.queue[i][0].qos, i))
            req, submit_round = self.queue[i]
            del self.queue[i]
            fresh = self.runner.init_carry(req.iinj)
            self._carry = write_lane(self._carry, k, fresh)
            ls = LaneState(lane=k, req=req, submit_round=submit_round,
                           admit_round=self.round, admit_time=time.monotonic(),
                           snapshot=fresh)
            self.lanes[k] = ls
            self.res.admitted += 1
            self._wait_rounds.append(self.round - submit_round)
            self.log(f"[serve] admitted rid={req.rid} -> lane {k} "
                     f"(waited {self.round - submit_round} rounds)")

    # --- per-lane lifecycle -----------------------------------------------

    def _finish(self, ls: LaneState, status: str, reason: str = "",
                harvest: bool = False):
        """Terminalize a lane: harvest its spike record (completed) or
        drop it (evicted), free the lane.  Either way the rid gets its
        one terminal ``TenantResult`` — no silent disappearance."""
        res = TenantResult(ls.req.rid, status, reason,
                           rounds=ls.rounds_run, retries=ls.retries,
                           wait_rounds=ls.admit_round - ls.submit_round,
                           health=ls.health())
        if harvest:
            rec = lane_slice(self._carry[2], ls.lane)
            res.times = np.asarray(rec.times)
            res.count = np.asarray(rec.count)
            res.overflow = int(rec.overflow)
        self.res.results[ls.req.rid] = res
        if status == "completed":
            self.res.completed += 1
        else:
            self.res.evicted += 1
        self.lanes[ls.lane] = None
        self.log(f"[serve] {status} rid={ls.req.rid}"
                 f"{' (' + reason + ')' if reason else ''} "
                 f"after {ls.rounds_run} rounds, {ls.retries} retries")

    def _quarantine(self, ls: LaneState):
        """Roll the lane back to its own last clean snapshot and schedule
        a bounded-backoff retry; exhausted budgets evict (detected, never
        silently spun)."""
        self.res.quarantines += 1
        ls.nonfinite_rounds += 1
        budget = ls.req.max_retries if ls.req.max_retries is not None \
            else self.backoff.max_retries
        self._carry = write_lane(self._carry, ls.lane, ls.snapshot)
        if ls.retries >= budget:
            self._finish(ls, "evicted", "retries_exhausted")
            return
        ls.retries += 1
        self.res.retried += 1
        delay = self.backoff.delay(ls.retries)
        ls.backoff_until = self.round + delay
        ls.quarantined = True
        self.log(f"[serve] quarantined rid={ls.req.rid} (retry "
                 f"{ls.retries}/{budget}, backoff {delay} rounds)")

    def _reactivate(self):
        for ls in self.lanes:
            if ls is not None and ls.quarantined \
                    and self.round >= ls.backoff_until:
                ls.quarantined = False
                self.log(f"[serve] retrying rid={ls.req.rid}")

    def _inject_fault(self):
        """Per-tenant poison: once the target tenant has run
        ``poison_at_round`` rounds, corrupt neuron ``poison_lane`` of its
        own lane (one injection; the retry after rollback is clean)."""
        if not self._poison_pending:
            return
        f = self.fault
        at = f.poison_at_round if f.poison_at_round is not None else 0
        for ls in self.lanes:
            if ls is not None and not ls.quarantined \
                    and ls.req.rid == f.poison_tenant \
                    and ls.rounds_run >= at:
                sts = self._carry[0]
                zn = sts.zn.at[ls.lane, f.poison_lane].set(f.poison_value)
                self._carry = (sts._replace(zn=zn),) + self._carry[1:]
                self._poison_pending = False
                self.log(f"[serve] poisoned rid={ls.req.rid} neuron "
                         f"{f.poison_lane} at tenant round {ls.rounds_run}")
                return

    # --- the service round -------------------------------------------------

    def _active(self):
        return [ls for ls in self.lanes
                if ls is not None and not ls.quarantined]

    def step(self) -> bool:
        """One service round; returns False when fully idle (no running
        or queued work)."""
        if self.fault is not None and self.fault.fail_at_round is not None \
                and self.round >= self.fault.fail_at_round:
            raise SimulatedFailure(self.round)
        self._reactivate()
        self._admit()
        self._inject_fault()
        active = self._active()
        if not active and not self.queue and \
                all(ls is None or not ls.quarantined for ls in self.lanes):
            return False

        if active:
            amask = np.zeros((self.L,), bool)
            iinj = np.zeros((self.L,), np.float64)
            kqos = np.zeros((self.L,), np.int32)
            for ls in active:
                amask[ls.lane] = True
                iinj[ls.lane] = ls.req.iinj
                kqos[ls.lane] = int(self.qos_caps.get(ls.req.qos, 0))
            t_prev = self._carry[0].t
            t0 = time.monotonic()
            self._carry = self._vround(self._carry, jnp.asarray(iinj),
                                       jnp.asarray(amask),
                                       jnp.asarray(kqos))
            jax.block_until_ready(self._carry[0].t)
            self.monitor.record(time.monotonic() - t0)
            for ls in active:
                ls.rounds_run += 1

            # per-tenant verdicts: each lane judged on its OWN neurons only
            verdict = xc.health_check_tenants(self._carry[0], t_prev)
            nonfinite = np.asarray(verdict["nonfinite_lanes"])
            solver_failed = np.asarray(verdict["solver_failed"])
            t_min = np.asarray(self._carry[0].t.min(axis=1))
            for ls in list(active):
                if nonfinite[ls.lane]:
                    self._quarantine(ls)
                elif solver_failed[ls.lane]:
                    # deterministic failure on finite state: retry can't help
                    self._finish(ls, "evicted", "solver_failure")
                else:
                    self._postround_clean(ls, t_min[ls.lane])

        self._shed_overload()
        self.round += 1
        self.res.rounds = self.round
        return True

    def _postround_clean(self, ls: LaneState, t_min: float):
        """Clean verdict: snapshot, then completion / deadline checks."""
        if ls.rounds_run - ls.snapshot_round >= self.snapshot_every:
            ls.snapshot = lane_slice(self._carry, ls.lane)
            ls.snapshot_round = ls.rounds_run
            if self.ckpt_dir and self.checkpoint_every and \
                    ls.rounds_run % self.checkpoint_every == 0:
                save_tenant_checkpoint(
                    self.ckpt_dir, ls.req.rid, ls.rounds_run,
                    self.runner.pack(ls.snapshot),
                    extras={"rid": ls.req.rid, "t_min": float(t_min)})
                self._snapshots_saved += 1
        target = ls.req.t_target if ls.req.t_target is not None \
            else self.t_end
        if t_min >= target - 1e-9:
            self._finish(ls, "completed", harvest=True)
            return
        if ls.req.deadline_rounds and ls.rounds_run >= ls.req.deadline_rounds:
            self._finish(ls, "evicted", "deadline_rounds")
            return
        if ls.req.deadline_s and \
                time.monotonic() - ls.admit_time >= ls.req.deadline_s:
            self._finish(ls, "evicted", "deadline_wall")

    # --- drivers ------------------------------------------------------------

    def run(self, max_rounds: int = 100_000) -> ServeResult:
        """Drive rounds until idle (or the bound); shutdown terminalizes
        every survivor explicitly — running tenants are evicted and
        queued requests rejected, all with reason \"shutdown\"."""
        while self.round < max_rounds and self.step():
            pass
        for ls in list(self.lanes):
            if ls is not None:
                self._finish(ls, "evicted", "shutdown")
        while self.queue:
            req, _ = self.queue.popleft()
            self._reject(req, "shutdown")
        self.res.health = self.health()
        return self.res.assert_accounting()

    def health(self) -> dict:
        w = self._wait_rounds
        return {
            "straggler": self.monitor.stats(),
            "backoff": {"max_retries": self.backoff.max_retries,
                        "budget_rounds": self.backoff.budget()},
            "quarantines": self.res.quarantines,
            "snapshots_saved": self._snapshots_saved,
            "admission_wait_rounds": {
                "mean": float(np.mean(w)) if w else 0.0,
                "max": int(max(w)) if w else 0},
            "queue_depth": len(self.queue),
            "shed": self.res.shed,
        }

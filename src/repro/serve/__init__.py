from repro.serve.service import ServeResult, SimService  # noqa: F401
from repro.serve.tenants import (LaneState, TenantRequest,  # noqa: F401
                                 TenantResult, lane_slice, stack_lanes,
                                 write_lane)

"""repro.sched — scheduler-side runtime structures for the FAP models.

``wheel``  - bucketed event-wheel (calendar-queue) spike-parcel queue:
             O(E) scatter insert, no global sort (the dense queue's
             per-round argsort bottleneck).
``api``    - the ``queue="dense"|"wheel"`` dispatch used by every
             execution model, plus jaxpr introspection helpers.

The matching Pallas kernel (fused horizon scatter-min + runnable mask +
earliest-K threshold selection) lives in ``repro.kernels.event_wheel``.
"""
from repro.sched.api import (QueueOps, edge_insert, gather_rows,  # noqa: F401
                             get_queue_ops, grouped_k, jaxpr_primitives,
                             scatter_rows)
from repro.sched.wheel import (WheelQueue, WheelSpec,  # noqa: F401
                               bucket_occupancy, deliver_until, insert,
                               insert_grouped, make_wheel, next_time,
                               segment_rank)

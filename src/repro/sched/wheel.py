"""Bucketed event-wheel (calendar-queue) runtime for the FAP scheduler.

``core/events.py`` realises spike-parcel delivery with a *global stable
argsort over all E candidate events* per scheduler round (to hand every
event a distinct free slot of its target neuron) plus a per-neuron argsort
over the whole capacity axis.  Under GSPMD that argsort lowers to a
distributed sort — the exact bottleneck ``distributed/fap_spmd.py`` flags.

The wheel replaces both sorts with O(E) scatter arithmetic, the classic
calendar-queue answer (Brette et al., q-bio/0611089):

  * each neuron owns ``n_buckets`` time buckets of ``bucket_slots`` slots;
    an event at time t hashes to bucket ``floor(t / bucket_width) mod B``;
  * slot assignment *within* a bucket needs only the rank of an event among
    the batch events that hit the same (neuron, bucket) — computed either
    from the static edge grouping (``insert_grouped``, the production
    fan-out path: zero extra work) or by ``segment_rank``'s iterative
    scatter-min (``insert``, the drop-in generic path);
  * the free slot for rank r is found by a cumsum over the S-slot bucket —
    prefix sums and argmax, never a sort.

Correctness never depends on the hash: a bucket is just a partition of the
unordered slot set, so wrap-around collisions only affect *capacity*
balance, not semantics.  ``deliver_until`` / ``next_time`` are masked
reductions over the flat slot view, bit-identical to the dense queue, so
the wheel is drop-in swappable (``queue="wheel"`` in every exec model) and
spike trains match the dense queue event-for-event when neither overflows.

Overflow stays *detected, never silent*: ``dropped`` accumulates exactly
like the dense queue (within a bucket, later-index events drop first —
the dense queue's stable-order semantics).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

INF = jnp.inf


class WheelSpec(NamedTuple):
    """Static wheel geometry (python constants, closed over by jit)."""
    n_buckets: int = 16
    bucket_slots: int = 4
    bucket_width: float = 0.5      # ms per bucket

    @property
    def capacity(self) -> int:
        return self.n_buckets * self.bucket_slots

    @classmethod
    def auto(cls, net, *, horizon_cap: float = 2.0, n_buckets: int = 16,
             slack: float = 1.25) -> "WheelSpec":
        """Size the wheel from the network itself (the ROADMAP follow-up).

        * ``bucket_width`` from the delay distribution: an event enters the
          wheel at most ``max_delay + horizon_cap`` ms ahead of its target's
          clock, so one wheel revolution (``n_buckets * width``) spans that
          horizon with ``slack`` headroom — in-flight events then occupy at
          most one epoch per bucket and wrap-around collisions stay rare
          (they would only cost capacity, never correctness).
        * ``bucket_slots`` from the in-degree *and* the delay clustering:
          the worst bucket load is a synchronized burst — every in-edge
          fires at once and the lognormal delay mode piles deliveries into
          one width-wide window.  For the grouped edge layout the exact
          per-neuron sliding-window burst load is computed from the delays
          themselves; otherwise an aligned per-(neuron, bucket) histogram
          (x2 for window alignment) bounds it.  Floored at the default 4.
        """
        import numpy as np
        d = np.asarray(net.delay)
        post = np.asarray(net.post)
        n, E = max(1, int(net.n)), int(d.shape[0])
        span = float(d.max()) + horizon_cap
        width = max(1e-3, slack * span / n_buckets)
        k_in = max(1, E // n)
        grouped = E == n * k_in and np.array_equal(
            post, np.repeat(np.arange(n, dtype=post.dtype), k_in))
        if grouped:
            rows = np.sort(d.reshape(n, k_in), axis=1)
            load = 1
            for j in range(k_in):
                within = (rows < rows[:, j: j + 1] + width).sum(axis=1) - j
                load = max(load, int(within.max()))
        else:
            b = np.floor(d / width).astype(np.int64)
            key = post.astype(np.int64) * (int(b.max()) + 1) + b
            load = 2 * int(np.unique(key, return_counts=True)[1].max())
        slots = max(4, int(np.ceil(slack * load)))
        return cls(n_buckets=n_buckets, bucket_slots=slots,
                   bucket_width=width)


class WheelQueue(NamedTuple):
    """Same field layout as ``events.EventQueue`` — the slot axis is the
    flattened (bucket, slot) view, so ``make_vardt_advance`` and the
    delivery reductions operate on it unchanged."""
    t: jnp.ndarray        # f64[N, B*S] delivery times (+inf = free)
    w_ampa: jnp.ndarray   # f64[N, B*S]
    w_gaba: jnp.ndarray   # f64[N, B*S]
    dropped: jnp.ndarray  # i32[] overflow counter


def make_wheel(n: int, spec: WheelSpec = WheelSpec(), dtype=jnp.float64) -> WheelQueue:
    cap = spec.capacity
    return WheelQueue(
        t=jnp.full((n, cap), INF, dtype),
        w_ampa=jnp.zeros((n, cap), dtype),
        w_gaba=jnp.zeros((n, cap), dtype),
        dropped=jnp.zeros((), jnp.int32),
    )


def _bucket_of(spec: WheelSpec, t_ev, valid):
    t_safe = jnp.where(valid, t_ev, 0.0)
    epoch = jnp.floor(t_safe / spec.bucket_width).astype(jnp.int32)
    return jnp.mod(epoch, spec.n_buckets)


def segment_rank(key, n_keys: int, max_rank: int):
    """Rank of each event within its key group, in event-index order.

    ``max_rank`` rounds of scatter-min over a key table: round r's winner
    per key (the lowest-index unplaced event) gets rank r.  Events beyond
    ``max_rank`` per key keep rank ``max_rank`` (they could never fit in a
    bucket of that many slots anyway).  O(E + n_keys) per round, no sort.
    """
    E = key.shape[0]
    idx = jnp.arange(E, dtype=jnp.int32)

    def body(r, c):
        rank, remaining = c
        k = jnp.where(remaining, key, n_keys)
        table = jnp.full((n_keys + 1,), E, jnp.int32).at[k].min(idx)
        win = jnp.logical_and(remaining, table[k] == idx)
        rank = jnp.where(win, r, rank)
        return rank, jnp.logical_and(remaining, ~win)

    rank0 = jnp.full((E,), max_rank, jnp.int32)
    rank, _ = jax.lax.fori_loop(0, max_rank, body, (rank0, key < n_keys))
    return rank


def _place(spec: WheelSpec, free_rows, rank, valid):
    """Map (bucket free-mask row [.., S], rank) -> (ok, slot-in-bucket).

    The rank-th event takes the (rank+1)-th free slot, located by a prefix
    sum over the S slots; ranks beyond the free count drop.
    """
    S = spec.bucket_slots
    csum = jnp.cumsum(free_rows.astype(jnp.int32), axis=-1)
    n_free = csum[..., -1]
    ok = jnp.logical_and(valid, jnp.logical_and(rank < S, rank < n_free))
    hit = jnp.logical_and(free_rows, csum == (rank + 1)[..., None])
    slot = jnp.argmax(hit, axis=-1).astype(jnp.int32)
    return ok, slot


def insert(spec: WheelSpec, eq: WheelQueue, target, t_ev, w_ampa, w_gaba,
           valid, rank: Optional[jnp.ndarray] = None,
           rank_impl: str = "auto", rank_domain: str = "global") -> WheelQueue:
    """Drop-in generic insert (same signature as ``events.insert``): E
    candidate events to arbitrary targets, O(E) scatters, no sort.

    ``rank`` may carry precomputed ranks within (target, bucket) groups
    (e.g. from a static edge layout); when None they are derived through
    ``kernels.event_wheel.ops.segment_rank`` — the pairwise Pallas tile
    kernel on real TPU (one VMEM pass, no per-round key table), the
    iterative scatter-min elsewhere (``rank_impl`` forces either).
    ``rank_domain="batch"`` tells the scatter ranking that E is small
    (a cap-bounded spike batch): keys are remapped to the dense [E]
    domain first so the per-round key table is O(E), not O(N*B).
    """
    n, cap = eq.t.shape
    B, S = spec.n_buckets, spec.bucket_slots
    bucket = _bucket_of(spec, t_ev, valid)
    tgt = jnp.where(valid, target, n)
    key = jnp.where(valid, target * B + bucket, n * B)
    if rank is None:
        from repro.kernels.event_wheel import ops as ew_ops
        rank = ew_ops.segment_rank(key, n * B, S, impl=rank_impl,
                                   domain=rank_domain)
    tgt_c = jnp.clip(tgt, 0, n - 1)
    free = jnp.isinf(eq.t).reshape(n, B, S)
    free_rows = free[tgt_c, bucket]                          # [E, S]
    ok, slot = _place(spec, free_rows, rank, valid)
    row = jnp.where(ok, tgt_c, n)                            # park drops OOB
    col = bucket * S + slot
    new_t = eq.t.at[row, col].set(t_ev, mode="drop")
    new_a = eq.w_ampa.at[row, col].set(w_ampa, mode="drop")
    new_g = eq.w_gaba.at[row, col].set(w_gaba, mode="drop")
    dropped = eq.dropped + jnp.sum(jnp.logical_and(valid, ~ok)).astype(jnp.int32)
    return WheelQueue(new_t, new_a, new_g, dropped)


def insert_grouped(spec: WheelSpec, eq: WheelQueue, t_ev, w_ampa, w_gaba,
                   valid) -> WheelQueue:
    """Fast-path insert for by-post-grouped traffic: row i of the [N, k]
    inputs holds neuron i's k in-edge candidates (the static fan-out
    layout of ``make_network`` / the shard-local SPMD exchange).

    Ranks within (neuron, bucket) come from pairwise equality against
    earlier in-edges of the same row — k(k-1)/2 vector ops, no scatter and
    no sort at all on the rank path.
    """
    n, k = t_ev.shape
    B, S = spec.n_buckets, spec.bucket_slots
    bucket = _bucket_of(spec, t_ev, valid)
    cols = []
    for i in range(k):
        r = jnp.zeros((n,), jnp.int32)
        for j in range(i):
            same = jnp.logical_and(bucket[:, j] == bucket[:, i], valid[:, j])
            r = r + same.astype(jnp.int32)
        cols.append(r)
    rank = jnp.stack(cols, axis=1)                           # [N, k]
    free = jnp.isinf(eq.t).reshape(n, B, S)
    free_rows = jnp.take_along_axis(free, bucket[:, :, None], axis=1)  # [N,k,S]
    ok, slot = _place(spec, free_rows, rank, valid)
    col = bucket * S + slot
    col = jnp.where(ok, col, eq.t.shape[1])                  # OOB -> dropped
    row = jnp.arange(n)[:, None]
    new_t = eq.t.at[row, col].set(t_ev, mode="drop")
    new_a = eq.w_ampa.at[row, col].set(w_ampa, mode="drop")
    new_g = eq.w_gaba.at[row, col].set(w_gaba, mode="drop")
    dropped = eq.dropped + jnp.sum(jnp.logical_and(valid, ~ok)).astype(jnp.int32)
    return WheelQueue(new_t, new_a, new_g, dropped)


def bucket_occupancy(spec: WheelSpec, eq: WheelQueue):
    """Occupancy telemetry for sizing: how full is the wheel right now?

    Returns a dict of
      per_bucket: i32[B] — occupied slots per bucket, summed over neurons,
      max_bucket: i32[]  — fullest (neuron, bucket) cell (== S means some
                  bucket is saturated: the next same-bucket event drops),
      occupied:   i32[]  — total pending events.
    """
    n = eq.t.shape[0]
    occ = (~jnp.isinf(eq.t)).reshape(n, spec.n_buckets, spec.bucket_slots)
    per_cell = occ.sum(axis=2).astype(jnp.int32)            # [N, B]
    return {
        "per_bucket": per_cell.sum(axis=0),
        "max_bucket": per_cell.max(),
        "occupied": per_cell.sum(),
    }


def next_time(eq: WheelQueue):
    """Earliest pending delivery time per neuron, +inf if none.  f64[N]."""
    return eq.t.min(axis=1)


def deliver_until(eq: WheelQueue, t_dl):
    """Pop all events with t <= t_dl (per neuron); return summed weights.

    Identical semantics (and identical code) to the dense queue — buckets
    need no cursor maintenance because slots are located by free-mask.
    """
    due = eq.t <= t_dl[:, None]
    wa = jnp.sum(jnp.where(due, eq.w_ampa, 0.0), axis=1)
    wg = jnp.sum(jnp.where(due, eq.w_gaba, 0.0), axis=1)
    cnt = due.sum(axis=1).astype(jnp.int32)
    new_t = jnp.where(due, INF, eq.t)
    return WheelQueue(new_t, eq.w_ampa, eq.w_gaba, eq.dropped), wa, wg, cnt

"""Queue-implementation dispatch: the ``queue="dense"|"wheel"`` knob.

Every execution model (exec_bsp, exec_fap, exec_speculative, the SPMD
round) builds a ``QueueOps`` at trace time and goes through it for all
queue traffic, so the dense argsort queue and the bucketed event wheel
stay drop-in interchangeable and separately testable.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.sched.wheel import WheelQueue, WheelSpec
from repro.sched import wheel as wh


class QueueOps(NamedTuple):
    name: str
    capacity: int
    make: Callable            # (n,) -> queue
    insert: Callable          # (eq, target[E], t[E], wa[E], wg[E], valid[E]) -> eq
    insert_grouped: Callable  # (eq, t[N,k], wa[N,k], wg[N,k], valid[N,k]) -> eq
    insert_batch: Callable    # insert for a small batch: flat in N (the
    #                           compact fan-out's per-spike edge batches)
    next_time: Callable       # (eq,) -> f64[N]
    deliver_until: Callable   # (eq, t_dl[N]) -> (eq, wa[N], wg[N], cnt[N])
    wrap: Callable            # (t, wa, wg, dropped) -> queue


def _dense_insert_grouped(eq, t_ev, w_ampa, w_gaba, valid):
    n, k = t_ev.shape
    tgt = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    return ev.insert(eq, tgt, t_ev.reshape(-1), w_ampa.reshape(-1),
                     w_gaba.reshape(-1), valid.reshape(-1))


def get_queue_ops(queue: str = "dense", *, ev_cap: int = 64,
                  wheel: WheelSpec = WheelSpec()) -> QueueOps:
    if queue == "dense":
        return QueueOps(
            name="dense", capacity=ev_cap,
            make=lambda n: ev.make_queue(n, ev_cap),
            insert=ev.insert,
            insert_grouped=_dense_insert_grouped,
            insert_batch=ev.insert_rows,
            next_time=ev.next_time,
            deliver_until=ev.deliver_until,
            wrap=ev.EventQueue,
        )
    if queue == "wheel":
        # the wheel's generic insert doubles as the batch insert: no slot
        # argsort anywhere, and on TPU the pairwise rank kernel is N-free.
        # The batch insert ranks in the dense [E] batch domain, so the
        # off-TPU scatter-min ranking allocates an O(E) key table for the
        # cap-bounded edge batches instead of the O(N*B) global table
        # (the full-E generic insert keeps the global domain: there
        # E ~ N*k and the remap's pairwise [E, E] compare would dominate).
        return QueueOps(
            name="wheel", capacity=wheel.capacity,
            make=lambda n: wh.make_wheel(n, wheel),
            insert=functools.partial(wh.insert, wheel),
            insert_grouped=functools.partial(wh.insert_grouped, wheel),
            insert_batch=functools.partial(wh.insert, wheel,
                                           rank_domain="batch"),
            next_time=wh.next_time,
            deliver_until=wh.deliver_until,
            wrap=WheelQueue,
        )
    raise ValueError(f"unknown queue implementation {queue!r}")


def gather_rows(eq, ids):
    """Per-neuron queue rows of a compacted id list (the active-set gather
    of the ``batch="compact"`` execution path).

    Both queue implementations share the [N, cap] flat slot layout
    (``WheelQueue`` docstring), so one gather serves either; ``ids`` must
    be pre-clipped to [0, N).  Returns (t, w_ampa, w_gaba) rows — the
    weights are read-only in the advance, only ``t`` is scattered back.
    """
    return eq.t[ids], eq.w_ampa[ids], eq.w_gaba[ids]


def scatter_rows(eq, ids, t_rows):
    """Write advanced delivery-time rows back; sentinel ids (>= N) drop.

    The vardt advance consumes events by overwriting their times with
    +inf and never touches the weight planes, so the scatter is a single
    [cap, Q] row write — valid for dense queue and wheel alike.  ``ids``
    must be unique in-range lanes (the compaction guarantees it): padding
    is remapped to distinct out-of-range ids so the write can claim
    ``unique_indices`` and skip XLA's duplicate-safe sequential scatter.
    """
    from repro.core import exec_common as xc
    return eq._replace(t=xc.scatter_at(eq.t, ids, t_rows))


def grouped_k(net):
    """Host-side check of ``make_network``'s static edge layout: edges
    grouped by postsynaptic neuron with uniform in-degree.  Returns the
    in-degree k when the layout holds, else None."""
    post = np.asarray(net.post)
    E, n = post.shape[0], int(net.n)
    if E % n == 0 and np.array_equal(
            post, np.repeat(np.arange(n, dtype=post.dtype), E // n)):
        return E // n
    return None


def edge_insert(qops: QueueOps, net) -> Callable:
    """Best insert path for a network's static edge list: when the grouped
    layout holds (``grouped_k``), fan-out events go through the grouped
    fast path (for the wheel: no scatter-min ranking, no sort of any
    kind); otherwise the generic insert."""
    k = grouped_k(net)
    if k is None:
        return qops.insert
    n = int(net.n)

    def ins(eq, target, t_ev, w_ampa, w_gaba, valid):
        return qops.insert_grouped(eq, t_ev.reshape(n, k),
                                   w_ampa.reshape(n, k),
                                   w_gaba.reshape(n, k),
                                   valid.reshape(n, k))

    return ins


def jaxpr_primitives(fn, *args, **kwargs) -> set:
    """All primitive names in fn's jaxpr, recursing into sub-jaxprs —
    used to certify the wheel insert path carries no ``sort``."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    prims: set = set()

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            prims.add(eqn.primitive.name)
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    walk(sub)

    def _subjaxprs(v):
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                yield from _subjaxprs(x)

    walk(closed.jaxpr)
    return prims

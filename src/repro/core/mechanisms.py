"""Membrane mechanisms: Hodgkin-Huxley channels, exponential synapses and a
complex (non-linear, correlated) plasticity mechanism.

The channel set is the HH formalism of paper Eq. 1 (Na: m^3 h, K: n^4, leak).
The *complex model* is a Graupner-Brunel-style calcium/efficacy pair with a
cubic ODE and correlated states (paper §2.2, refs [12,13]) — the case that
motivates a fully-implicit (non-staggered) solver: it cannot be solved by the
staggered linear-equation trick of simple models.

All rate functions are written with singularity-safe ``exprel`` so they are
differentiable everywhere (needed by the dense-Jacobian test oracle).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

# --- channel densities / reversal potentials (squid-axon HH, NEURON defaults) ---
GNABAR = 0.12      # S/cm^2
GKBAR = 0.036      # S/cm^2
GLBAR = 0.0003     # S/cm^2
ENA = 50.0         # mV
EK = -77.0         # mV
EL = -54.3         # mV
E_AMPA = 0.0       # mV
E_GABA = -80.0     # mV
TAU_AMPA = 2.0     # ms (single-exponential decay conductance)
TAU_GABA = 8.0     # ms

# S/cm^2 * um^2 -> uS : 1 um^2 = 1e-8 cm^2, 1 S = 1e6 uS  => factor 1e-2
S_PER_CM2_TO_US_PER_UM2 = 1e-2


def exprel(x):
    """x / (exp(x) - 1), singularity-safe (-> 1 at x=0)."""
    x_safe = jnp.where(jnp.abs(x) < 1e-9, 1e-9, x)
    out = x_safe / jnp.expm1(x_safe)
    return jnp.where(jnp.abs(x) < 1e-9, 1.0 - x / 2.0, out)


# --- HH gating rates (V in mV, rates in 1/ms) ---------------------------------
def alpha_m(v):
    return 1.0 * exprel(-(v + 40.0) / 10.0)


def beta_m(v):
    return 4.0 * jnp.exp(-(v + 65.0) / 18.0)


def alpha_h(v):
    return 0.07 * jnp.exp(-(v + 65.0) / 20.0)


def beta_h(v):
    return 1.0 / (1.0 + jnp.exp(-(v + 35.0) / 10.0))


def alpha_n(v):
    return 0.1 * exprel(-(v + 55.0) / 10.0)


def beta_n(v):
    return 0.125 * jnp.exp(-(v + 65.0) / 80.0)


class GateRates(NamedTuple):
    a_m: jnp.ndarray
    b_m: jnp.ndarray
    a_h: jnp.ndarray
    b_h: jnp.ndarray
    a_n: jnp.ndarray
    b_n: jnp.ndarray


def gate_rates(v) -> GateRates:
    return GateRates(alpha_m(v), beta_m(v), alpha_h(v), beta_h(v),
                     alpha_n(v), beta_n(v))


def gate_inf_tau(v):
    """Steady state and time constant per gate: x_inf = a/(a+b), tau = 1/(a+b)."""
    r = gate_rates(v)
    s_m, s_h, s_n = r.a_m + r.b_m, r.a_h + r.b_h, r.a_n + r.b_n
    return ((r.a_m / s_m, 1.0 / s_m), (r.a_h / s_h, 1.0 / s_h),
            (r.a_n / s_n, 1.0 / s_n))


def gate_derivs(v, m, h, n):
    """dx/dt = alpha(V)(1-x) - beta(V)x for the three HH gates."""
    r = gate_rates(v)
    dm = r.a_m * (1.0 - m) - r.b_m * m
    dh = r.a_h * (1.0 - h) - r.b_h * h
    dn = r.a_n * (1.0 - n) - r.b_n * n
    return dm, dh, dn


def channel_conductances(area, m, h, n):
    """Per-compartment ionic conductances in uS: (g_na, g_k, g_l)."""
    f = area * S_PER_CM2_TO_US_PER_UM2
    g_na = GNABAR * f * m ** 3 * h
    g_k = GKBAR * f * n ** 4
    g_l = GLBAR * f * jnp.ones_like(m)
    return g_na, g_k, g_l


def ionic_current(area, v, m, h, n):
    """Total ionic membrane current per compartment, nA (positive = outward)."""
    g_na, g_k, g_l = channel_conductances(area, m, h, n)
    return g_na * (v - ENA) + g_k * (v - EK) + g_l * (v - EL)


# --- complex correlated mechanism (Graupner-Brunel-like, paper §2.2) ----------
RHO_STAR = 0.5
TAU_RHO = 150.0    # ms
TAU_CA = 20.0      # ms
GAMMA_P = 0.6
GAMMA_D = 0.3
THETA_P = 1.3
THETA_D = 1.0
CA_JUMP = 0.7      # calcium influx per presynaptic event


def _sig(x, k=20.0):
    return 1.0 / (1.0 + jnp.exp(-k * x))


def plasticity_derivs(ca, rho):
    """Correlated non-linear pair: cubic efficacy ODE driven by calcium.

    d ca/dt = -ca / TAU_CA                       (+ CA_JUMP per synaptic event)
    d rho/dt = (-rho(1-rho)(RHO*-rho) + GAMMA_P(1-rho)sig(ca-THETA_P)
                - GAMMA_D rho sig(ca-THETA_D)) / TAU_RHO
    """
    dca = -ca / TAU_CA
    drho = (-rho * (1.0 - rho) * (RHO_STAR - rho)
            + GAMMA_P * (1.0 - rho) * _sig(ca - THETA_P)
            - GAMMA_D * rho * _sig(ca - THETA_D)) / TAU_RHO
    return dca, drho

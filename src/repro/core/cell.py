"""Assembled compartmental neuron model: state packing, RHS, Jacobian terms.

State vector layout for one neuron with C compartments (paper Eq. 1/2):

    y = [ V(0..C) | m(0..C) | h(0..C) | n(0..C) | g_ampa | g_gaba (| ca | rho) ]

Synaptic conductances are aggregate single-exponential states attached to the
soma (compartment 0); a synaptic *event* is a discontinuity ``g += w`` — the
IVP reset of paper §2.3.  The optional (ca, rho) pair is the complex
correlated mechanism that requires fully-implicit resolution (paper §2.2).

``CellModel`` exposes:
  rhs(t, y, iinj)            full ODE right-hand side  (the CVODE f)
  jac_terms(y)               the Hines-structured approximation to df/dy used
                             as the Newton matrix  M = I - gamma*J  (NEURON's
                             default preconditioner, paper §2.3)
  solve_newton_mat(y, gamma, b)   solves  (I - gamma*J~) x = b  in O(C)
  newton_setup(y, gamma)     assembles + factors M once; returns a flat
                             factor vector (CVODE's lsetup)
  newton_solve(factors, b)   back-solves against stored factors in two
                             O(C) sweeps (CVODE's lsolve) — the pair
                             composes to solve_newton_mat, letting the
                             integrator reuse one setup across Newton
                             iterations and accepted steps
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mechanisms as mech
from repro.core.hines import (hines_assemble, hines_factor, hines_solve,
                              hines_solve_factored)
from repro.core.morphology import Morphology


class CellParams(NamedTuple):
    """Traced per-neuron constants (arrays so networks can be heterogeneous)."""

    parent: jnp.ndarray      # i32[C]
    area: jnp.ndarray        # f64[C] um^2
    cap: jnp.ndarray         # f64[C] nF
    g_axial: jnp.ndarray     # f64[C] uS


class CellModel:
    """Static model description; all methods are jit/vmap friendly."""

    def __init__(self, morph: Morphology, with_plasticity: bool = False):
        self.morph = morph
        self.C = morph.n_comp
        self.with_plasticity = bool(with_plasticity)
        self.n_syn = 2
        self.n_extra = 2 if with_plasticity else 0
        self.n_state = 4 * self.C + self.n_syn + self.n_extra
        self.params = CellParams(
            parent=jnp.asarray(morph.parent, jnp.int32),
            area=jnp.asarray(morph.area),
            cap=jnp.asarray(morph.cap),
            g_axial=jnp.asarray(morph.g_axial),
        )

    # ---- packing -------------------------------------------------------------
    def split(self, y):
        C = self.C
        v = y[:C]
        m = y[C:2 * C]
        h = y[2 * C:3 * C]
        n = y[3 * C:4 * C]
        g_ampa = y[4 * C]
        g_gaba = y[4 * C + 1]
        extra = y[4 * C + 2:]
        return v, m, h, n, g_ampa, g_gaba, extra

    def pack(self, v, m, h, n, g_ampa, g_gaba, extra=None):
        parts = [v, m, h, n, jnp.stack([g_ampa, g_gaba])]
        if self.with_plasticity:
            parts.append(extra)
        return jnp.concatenate(parts)

    @property
    def idx_vsoma(self) -> int:
        return 0

    @property
    def idx_g_ampa(self) -> int:
        return 4 * self.C

    @property
    def idx_g_gaba(self) -> int:
        return 4 * self.C + 1

    @property
    def idx_ca(self) -> int:
        return 4 * self.C + 2

    def init_state(self, v0: float = -65.0):
        """Gates at steady state for v0, synapses at zero."""
        v = jnp.full((self.C,), v0)
        (m_inf, _), (h_inf, _), (n_inf, _) = mech.gate_inf_tau(v)
        g0 = jnp.zeros(())
        extra = jnp.array([0.0, 0.2]) if self.with_plasticity else None
        return self.pack(v, m_inf, h_inf, n_inf, g0, g0, extra)

    # ---- dynamics --------------------------------------------------------------
    def _syn_current_soma(self, v_soma, g_ampa, g_gaba):
        return g_ampa * (v_soma - mech.E_AMPA) + g_gaba * (v_soma - mech.E_GABA)

    def rhs(self, t, y, iinj=0.0):
        """f(t, y): full right-hand side; iinj = injected soma current (nA)."""
        del t
        v, m, h, n, g_ampa, g_gaba, extra = self.split(y)
        p = self.params
        i_ion = mech.ionic_current(p.area, v, m, h, n)        # nA, outward
        # axial tree currents (to-parent coupling, symmetric)
        dv_num = -i_ion
        vp = v[p.parent]                                       # parent voltage (junk at root)
        flow = p.g_axial * (vp - v)                            # nA into i from parent
        flow = flow.at[0].set(0.0)
        dv_num = dv_num + flow
        # each child pushes the opposite flow onto its parent
        dv_num = dv_num.at[p.parent].add(-flow)
        i_syn = self._syn_current_soma(v[0], g_ampa, g_gaba)
        dv_num = dv_num.at[0].add(-i_syn + iinj)
        dv = dv_num / p.cap
        dm, dh, dn = mech.gate_derivs(v, m, h, n)
        dg_a = -g_ampa / mech.TAU_AMPA
        dg_g = -g_gaba / mech.TAU_GABA
        if self.with_plasticity:
            dca, drho = mech.plasticity_derivs(extra[0], extra[1])
            dextra = jnp.stack([dca, drho])
        else:
            dextra = None
        return self.pack(dv, dm, dh, dn, dg_a, dg_g, dextra)

    # ---- structured Newton matrix (paper §2.3 preconditioner) -------------------
    def jac_terms(self, y):
        """Terms of the Hines-structured J~:

        voltage rows:  dVdot/dV ~ -(g_ion_tot + g_axial couplings)/cap  (tree)
        gate rows:     dxdot/dx = -(alpha+beta)        (diagonal)
        synapse rows:  -1/tau                          (diagonal)
        plasticity:    2x2 block treated diagonally via autodiff diag
        Off-diagonal V<->x couplings are dropped (inexact Newton; NEURON default).
        """
        v, m, h, n, g_ampa, g_gaba, extra = self.split(y)
        g_na, g_k, g_l = mech.channel_conductances(self.params.area, m, h, n)
        g_tot = g_na + g_k + g_l
        g_tot = g_tot.at[0].add(g_ampa + g_gaba)
        r = mech.gate_rates(v)
        diag_gates = jnp.concatenate([-(r.a_m + r.b_m), -(r.a_h + r.b_h),
                                      -(r.a_n + r.b_n)])
        diag_syn = jnp.array([-1.0 / mech.TAU_AMPA, -1.0 / mech.TAU_GABA])
        if self.with_plasticity:
            dfun = lambda e: jnp.stack(mech.plasticity_derivs(e[0], e[1]))
            jdiag = jnp.diagonal(jax.jacfwd(dfun)(extra))
            diag_extra = jdiag
        else:
            diag_extra = jnp.zeros((0,))
        return g_tot, diag_gates, diag_syn, diag_extra

    def solve_newton_mat(self, y, gamma, b, mode: str = "neuron"):
        """Solve (I - gamma*J~) x = b with one Hines solve + diagonal solves.

        mode="neuron": NEURON's default preconditioner — V<->gate couplings
        dropped (paper §2.3).  mode="schur": beyond-paper exact elimination
        of the HH gate block into the voltage system (the V/gate coupling is
        local per compartment, so the Schur complement stays Hines-shaped);
        Newton then converges in fewer iterations near spikes at the cost of
        one rate-derivative evaluation (EXPERIMENTS.md §Perf, neuro side).
        """
        C = self.C
        g_tot, diag_gates, diag_syn, diag_extra = self.jac_terms(y)
        p = self.params
        bv = b[:C] * p.cap / gamma
        diag_v = p.cap / gamma + g_tot

        if mode == "schur":
            v, m, h, n, g_ampa, g_gaba, extra = self.split(y)
            f = mech.S_PER_CM2_TO_US_PER_UM2 * p.area
            # cap * dVdot/dx = -(dg/dx) (V - E_x)
            Jvm = -(mech.GNABAR * f * 3.0 * m ** 2 * h) * (v - mech.ENA)
            Jvh = -(mech.GNABAR * f * m ** 3) * (v - mech.ENA)
            Jvn = -(mech.GKBAR * f * 4.0 * n ** 3) * (v - mech.EK)
            # dxdot/dV via one jvp along ones (exact, elementwise)
            _, (Jmv, Jhv, Jnv) = jax.jvp(
                lambda vv: mech.gate_derivs(vv, m, h, n), (v,),
                (jnp.ones_like(v),))
            dm, dh, dn = (diag_gates[:C], diag_gates[C:2 * C],
                          diag_gates[2 * C:3 * C])
            bm, bh, bn = b[C:2 * C], b[2 * C:3 * C], b[3 * C:4 * C]
            den_m, den_h, den_n = (1.0 - gamma * dm, 1.0 - gamma * dh,
                                   1.0 - gamma * dn)
            # Schur diagonal correction and rhs folding
            diag_v = diag_v - gamma * (Jvm * Jmv / den_m + Jvh * Jhv / den_h
                                       + Jvn * Jnv / den_n)
            bv = bv + (Jvm * bm / den_m + Jvh * bh / den_h + Jvn * bn / den_n)
            # synapse coupling into the soma V row (dg/dt is V-independent,
            # so this is rhs-only: no diagonal correction)
            den_ga = 1.0 + gamma / mech.TAU_AMPA
            den_gb = 1.0 + gamma / mech.TAU_GABA
            bv = bv.at[0].add(-(v[0] - mech.E_AMPA) * b[self.idx_g_ampa] / den_ga
                              - (v[0] - mech.E_GABA) * b[self.idx_g_gaba] / den_gb)

        d = hines_assemble(p.parent, p.g_axial, diag_v)
        xv = hines_solve(p.parent, p.g_axial, d, bv)
        rest_diag = jnp.concatenate([diag_gates, diag_syn, diag_extra])
        xr = b[C:] / (1.0 - gamma * rest_diag)
        if mode == "schur":
            # back-substitute exact gate corrections
            gate_corr = jnp.concatenate([
                gamma * Jmv * xv / den_m, gamma * Jhv * xv / den_h,
                gamma * Jnv * xv / den_n])
            xr = xr.at[: 3 * C].add(gate_corr)
        return jnp.concatenate([xv, xr])

    # ---- split setup/solve (factor reuse across Newton iterations) ---------------
    def n_factors(self, mode: str = "neuron") -> int:
        """Length of the flat factor vector returned by ``newton_setup``.

        Flat (not a nested pytree) so it rides ``BDFState`` as one plain
        array leaf: checkpointing, lane gather/scatter and SPMD sharding
        treat it like any other per-neuron state row.
        """
        base = self.n_state + self.C          # d_elim | vscale | rest_den
        return base + 6 * self.C + 2 if mode == "schur" else base

    def newton_setup(self, y, gamma, mode: str = "neuron"):
        """Assemble and factor M = I - gamma*J~ at (y, gamma); flat f64 vector.

        Layout: ``[d_elim(C) | vscale(C) | rest_den(n_state-C)]`` plus, in
        schur mode, the rhs-folding and gate-correction coefficient rows
        ``[Jvm/den_m, Jvh/den_h, Jvn/den_n, g*Jmv/den_m, g*Jhv/den_h,
        g*Jnv/den_n (C each) | syn folds (2)]``.  Everything downstream of
        (y, gamma) is precomputed, so ``newton_solve`` needs no rhs
        evaluation, no rate derivatives, and no elimination sweep.
        """
        C = self.C
        g_tot, diag_gates, diag_syn, diag_extra = self.jac_terms(y)
        p = self.params
        vscale = p.cap / gamma
        diag_v = vscale + g_tot
        parts = []

        if mode == "schur":
            v, m, h, n, g_ampa, g_gaba, extra = self.split(y)
            f = mech.S_PER_CM2_TO_US_PER_UM2 * p.area
            Jvm = -(mech.GNABAR * f * 3.0 * m ** 2 * h) * (v - mech.ENA)
            Jvh = -(mech.GNABAR * f * m ** 3) * (v - mech.ENA)
            Jvn = -(mech.GKBAR * f * 4.0 * n ** 3) * (v - mech.EK)
            _, (Jmv, Jhv, Jnv) = jax.jvp(
                lambda vv: mech.gate_derivs(vv, m, h, n), (v,),
                (jnp.ones_like(v),))
            dm, dh, dn = (diag_gates[:C], diag_gates[C:2 * C],
                          diag_gates[2 * C:3 * C])
            den_m, den_h, den_n = (1.0 - gamma * dm, 1.0 - gamma * dh,
                                   1.0 - gamma * dn)
            diag_v = diag_v - gamma * (Jvm * Jmv / den_m + Jvh * Jhv / den_h
                                       + Jvn * Jnv / den_n)
            den_ga = 1.0 + gamma / mech.TAU_AMPA
            den_gb = 1.0 + gamma / mech.TAU_GABA
            parts = [Jvm / den_m, Jvh / den_h, Jvn / den_n,
                     gamma * Jmv / den_m, gamma * Jhv / den_h,
                     gamma * Jnv / den_n,
                     jnp.stack([(v[0] - mech.E_AMPA) / den_ga,
                                (v[0] - mech.E_GABA) / den_gb])]

        d = hines_assemble(p.parent, p.g_axial, diag_v)
        d_elim = hines_factor(p.parent, p.g_axial, d)
        rest_diag = jnp.concatenate([diag_gates, diag_syn, diag_extra])
        rest_den = 1.0 - gamma * rest_diag
        return jnp.concatenate([d_elim, vscale, rest_den] + parts)

    def newton_solve(self, factors, b, mode: str = "neuron"):
        """Solve (I - gamma*J~) x = b against stored ``newton_setup`` factors.

        Two O(C) Hines sweeps plus elementwise work — no assembly, no
        elimination, no rate evaluation.  Composes with ``newton_setup``
        to the same solution as ``solve_newton_mat`` (to rounding: the
        cached reciprocal groupings reassociate a few products).
        """
        C = self.C
        p = self.params
        d_elim = factors[:C]
        vscale = factors[C:2 * C]
        rest_den = factors[2 * C:C + self.n_state]
        bv = b[:C] * vscale

        if mode == "schur":
            o = C + self.n_state
            fm, fh, fn = (factors[o:o + C], factors[o + C:o + 2 * C],
                          factors[o + 2 * C:o + 3 * C])
            gm, gh, gn = (factors[o + 3 * C:o + 4 * C],
                          factors[o + 4 * C:o + 5 * C],
                          factors[o + 5 * C:o + 6 * C])
            sa, sg = factors[o + 6 * C], factors[o + 6 * C + 1]
            bm, bh, bn = b[C:2 * C], b[2 * C:3 * C], b[3 * C:4 * C]
            bv = bv + fm * bm + fh * bh + fn * bn
            bv = bv.at[0].add(-sa * b[self.idx_g_ampa]
                              - sg * b[self.idx_g_gaba])

        xv = hines_solve_factored(p.parent, p.g_axial, d_elim, bv)
        xr = b[C:] / rest_den
        if mode == "schur":
            gate_corr = jnp.concatenate([gm * xv, gh * xv, gn * xv])
            xr = xr.at[:3 * C].add(gate_corr)
        return jnp.concatenate([xv, xr])

    # ---- events ------------------------------------------------------------------
    def apply_event(self, y, w_ampa, w_gaba):
        """Deliver aggregated synaptic weights (the discontinuity)."""
        y = y.at[self.idx_g_ampa].add(w_ampa)
        y = y.at[self.idx_g_gaba].add(w_gaba)
        if self.with_plasticity:
            y = y.at[self.idx_ca].add(mech.CA_JUMP * (w_ampa > 0))
        return y

    # ---- dense oracle (tests only) -------------------------------------------------
    def dense_jacobian(self, t, y, iinj=0.0):
        return jax.jacfwd(lambda yy: self.rhs(t, yy, iinj))(y)


def weighted_rms(x, y, atol, rtol):
    """CVODE WRMS norm with ewt_i = 1/(rtol*|y_i| + atol)."""
    w = 1.0 / (rtol * jnp.abs(y) + atol)
    return jnp.sqrt(jnp.mean((x * w) ** 2))

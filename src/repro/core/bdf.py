"""Variable-order variable-timestep BDF integrator (CVODE reimplemented in JAX).

This is the paper's fully-implicit solver (§2.3, Eq. 2): the fixed-leading-
coefficient BDF(1..5) of CVODE [Cohen & Hindmarsh 1996] in Nordsieck form,
with

  * the l / tq coefficient recurrences of SUNDIALS' cvSetBDF / cvSetTqBDF,
  * a modified-Newton corrector whose linear solves use the Hines-structured
    approximate Jacobian M = I - gamma*J~ (NEURON's default preconditioner),
  * WRMS-norm local error test, eta_{q-1}/eta_q/eta_{q+1} order selection
    (cvPrepareNextStep / cvAdjust{Increase,Decrease}BDF),
  * tstop semantics: a step never crosses ``t_limit`` — this is what makes the
    FAP execution model *non-speculative* (no backstepping ever needed),
  * IVP-reset on synaptic discontinuities (order -> 1, fresh h, history
    discarded) — the cost the paper's event-grouping variants amortise,
  * a CVODE-grade Jacobian-freshness policy (``jac_policy="reuse"``, the
    default): the Newton matrix M = I - gamma*J~ is assembled and factored
    ONCE per setup (``CellModel.newton_setup``, CVODE's lsetup) and the
    stored factors are reused across Newton iterations *and* accepted
    steps; a rebuild happens only on gamma drift (|gamma/gamma_saved - 1|
    > DGMAX), a periodic MSBP step counter, convergence-rate decay
    (crate > CRDOWN after a multi-iteration solve), or after a Newton
    convergence failure.  A convergence failure with *stale* factors
    first retries the same step with a fresh setup (CVODE's
    CV_FAIL_BAD_J path) before shrinking h.  Stale factors change the
    Newton iteration count, never the accepted state beyond tolerance —
    the corrector still converges to the exact implicit solution.
    ``jac_policy="iteration"`` is the legacy knob: re-assemble/factor on
    every Newton iteration, lowered computation identical to the
    historical path, and
  * ``method="ndf"``: the Klopfenstein/Shampine NDF error constants of
    MATLAB's ode15s wired into the BDF tq coefficients — same corrector,
    kappa-modified error weighting, h larger by |C_ndf/C_bdf|^(-1/(q+1))
    at equal tolerance (up to ~26% more step at q=2).

Every function is pure and ``vmap``-compatible: a network of neurons is a
vmapped pytree of ``BDFState`` with *independent* (t, h, q) per neuron — the
essence of the paper's per-neuron variable stepping.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

QMAX = 5
LMAX = QMAX + 1           # zn rows: 0..QMAX

# CVODE constants
ETAMX1 = 1.0e4            # etamax after the very first step
ETAMX = 10.0              # etamax otherwise
ETAMIN_EF = 0.1           # min eta after an error-test failure
ETAMXF = 0.2              # max eta after an error-test failure
ETACF = 0.25              # eta after a Newton-convergence failure
THRESH = 1.5              # order/step change threshold
BIAS1, BIAS2, BIAS3 = 6.0, 6.0, 10.0
ADDON = 1.0e-6
NLS_COEF = 0.1
CRDOWN = 0.3
RDIV = 2.0
MAX_NEWTON = 4
MAX_NCF = 10
MAX_NEF = 7
HMIN = 1.0e-9             # ms
MAX_ATTEMPTS = 40

# Jacobian-freshness policy (cvLSetup decision in cvNlsNewton)
MSBP = 20                 # max steps between setups
DGMAX = 0.3               # |gamma/gamma_saved - 1| beyond which factors rebuild
MAX_NCF_RESTART = 4       # consecutive conv failures before the q->1 restart

# NDF (Shampine & Reichelt, ode15s): kappa-modified BDF error constants.
# ratio[k] = |1 + (k+1) kappa_k gamma_k| is the NDF/BDF error-constant
# ratio at order k (gamma_k = sum_{j<=k} 1/j); scaling the tq error-test
# coefficients by it accepts steps larger by ratio^(-1/(k+1)) at equal
# tolerance.  kappa_5 = 0: BDF5 unchanged (NDF5 would not be stable).
_NDF_KAPPA = np.array([0.0, -0.1850, -1.0 / 9.0, -0.0823, -0.0415, 0.0])
_NDF_RATIO = np.ones((LMAX + 1,))
for _k in range(1, QMAX + 1):
    _gamk = sum(1.0 / _j for _j in range(1, _k + 1))
    _NDF_RATIO[_k] = abs(1.0 + (_k + 1) * _NDF_KAPPA[_k] * _gamk)
del _k, _gamk


class BDFState(NamedTuple):
    t: jnp.ndarray            # f64[]
    h: jnp.ndarray            # f64[] current (scaled-into-zn) step size
    q: jnp.ndarray            # i32[]
    zn: jnp.ndarray           # f64[LMAX, n] Nordsieck array
    tau: jnp.ndarray          # f64[LMAX+1]  tau[1..q(+1)] recent step sizes
    qwait: jnp.ndarray        # i32[]
    etamax: jnp.ndarray       # f64[]
    acor_save: jnp.ndarray    # f64[n] correction of previous step
    nst: jnp.ndarray          # i32[] accepted steps
    nfe: jnp.ndarray          # i32[] rhs evaluations
    nni: jnp.ndarray          # i32[] newton iterations
    netf: jnp.ndarray         # i32[] error-test failures
    nncf: jnp.ndarray         # i32[] newton-convergence failures
    nreset: jnp.ndarray       # i32[] IVP resets (event deliveries)
    failed: jnp.ndarray       # bool[]
    # ---- Jacobian-freshness policy (jac_policy="reuse") ----------------
    gamma_saved: jnp.ndarray  # f64[] gamma the stored factors were built at
    nstlp: jnp.ndarray        # i32[] nst at the last setup (MSBP counter)
    nsetups: jnp.ndarray      # i32[] Newton-matrix assemblies+factorizations
    jbad: jnp.ndarray         # bool[] factors flagged stale: setup next attempt
    factors: jnp.ndarray      # f64[n_factors] flat newton_setup factor vector


class BDFOptions(NamedTuple):
    atol: float = 1.0e-3
    rtol: float = 0.0
    hmax: float = 1.0e9
    h0: float = -1.0          # <=0: use heuristic
    precond: str = "neuron"   # "neuron" (paper default) | "schur" (exact HH block)
    method: str = "bdf"       # "bdf" | "ndf" (kappa-modified error constants)
    jac_policy: str = "reuse" # "reuse" (CVODE freshness policy) |
    #                           "iteration" (legacy: setup every Newton iter)


def _wrms(x, y, opts: BDFOptions):
    w = 1.0 / (opts.rtol * jnp.abs(y) + opts.atol)
    return jnp.sqrt(jnp.mean((x * w) ** 2))


def reinit(model, t, y, iinj, opts: BDFOptions, counters=None,
           f=None) -> BDFState:
    """(Re-)initialise the IVP at (t, y): order 1, heuristic h0.

    ``f`` may carry a precomputed rhs evaluation at (t, y) — the fused
    deliver/step path (``step_or_deliver``) shares the rhs stream of the
    Newton corrector with the reset heuristic instead of paying a second
    evaluation.

    The factor cache starts empty (``jbad=True``): the first step attempt
    runs a setup, so a reset costs no factorization of its own."""
    if opts.method not in ("bdf", "ndf"):
        raise ValueError(f"unknown method {opts.method!r}")
    if opts.jac_policy not in ("reuse", "iteration"):
        raise ValueError(f"unknown jac_policy {opts.jac_policy!r}")
    if f is None:
        f = model.rhs(t, y, iinj)
    fn = _wrms(f, y, opts)
    h_heur = 0.5 / (fn + 1.0e-10)
    h = jnp.where(opts.h0 > 0, opts.h0, jnp.clip(h_heur, 1.0e-6, 1.0))
    h = jnp.minimum(h, opts.hmax)
    n = y.shape[0]
    zn = jnp.zeros((LMAX, n), y.dtype).at[0].set(y).at[1].set(h * f)
    tau = jnp.zeros((LMAX + 1,), y.dtype).at[1].set(h)
    z = jnp.zeros((), jnp.int32)
    c = counters or (z, z + 1, z, z, z, z, z)
    return BDFState(t=jnp.asarray(t, y.dtype), h=h, q=jnp.ones((), jnp.int32),
                    zn=zn, tau=tau, qwait=jnp.full((), 2, jnp.int32),
                    etamax=jnp.asarray(ETAMX1), acor_save=jnp.zeros_like(y),
                    nst=c[0], nfe=c[1], nni=c[2], netf=c[3], nncf=c[4],
                    nreset=c[5], failed=jnp.zeros((), bool),
                    gamma_saved=jnp.ones((), y.dtype),
                    nstlp=jnp.asarray(c[0], jnp.int32), nsetups=c[6],
                    jbad=jnp.ones((), bool),
                    factors=jnp.zeros((model.n_factors(opts.precond),),
                                      y.dtype))


# --------------------------------------------------------------------------
# coefficient machinery (cvSetBDF / cvSetTqBDF), masked static loops to QMAX
# --------------------------------------------------------------------------
def _set_bdf_coeffs(q, h, tau, method: str = "bdf"):
    qf = q.astype(h.dtype)
    l = jnp.zeros((LMAX,), h.dtype).at[0].set(1.0).at[1].set(1.0)
    alpha0 = jnp.asarray(-1.0, h.dtype)
    hsum = h
    xi_inv = jnp.asarray(1.0, h.dtype)
    xistar_inv = jnp.asarray(1.0, h.dtype)

    # for (j=2; j < q; j++)
    for j in range(2, QMAX):
        active = j < q
        hsum_n = hsum + tau[j - 1]
        xi_inv_n = h / hsum_n
        alpha0_n = alpha0 - 1.0 / j
        l_n = l.at[1:].add(l[:-1] * xi_inv_n)
        hsum = jnp.where(active, hsum_n, hsum)
        alpha0 = jnp.where(active, alpha0_n, alpha0)
        l = jnp.where(active, l_n, l)

    # j = q  (only when q > 1)
    active = q > 1
    alpha0_n = alpha0 - 1.0 / jnp.maximum(qf, 1.0)
    xistar_inv_n = -l[1] - alpha0_n
    hsum_n = hsum + tau[jnp.maximum(q - 1, 1)]
    xi_inv_n = h / hsum_n
    alpha0_hat_n = -l[1] - xi_inv_n
    l_n = l.at[1:].add(l[:-1] * xistar_inv_n)
    alpha0 = jnp.where(active, alpha0_n, alpha0)
    xistar_inv = jnp.where(active, xistar_inv_n, xistar_inv)
    hsum = jnp.where(active, hsum_n, hsum)
    xi_inv = jnp.where(active, xi_inv_n, xi_inv)
    alpha0_hat = jnp.where(active, alpha0_hat_n, alpha0)
    l = jnp.where(active, l_n, l)

    # tq coefficients (cvSetTqBDF)
    A1 = 1.0 - alpha0_hat + alpha0
    A2 = 1.0 + qf * A1
    tq2 = jnp.abs(A1 / (alpha0 * A2))
    lq = l[jnp.clip(q, 0, QMAX)]
    tq5 = jnp.abs(A2 * xistar_inv / (lq * xi_inv))
    # order q-1 coefficient
    Cc = xistar_inv / lq
    A3 = alpha0 + 1.0 / qf
    A4 = alpha0_hat + xi_inv
    Cpinv = (1.0 - A4 + A3) / A3
    tq1 = jnp.where(q > 1, jnp.abs(Cc * Cpinv), 1.0)
    # order q+1 coefficient
    hsum_p = hsum + tau[jnp.clip(q, 1, LMAX)]
    xi_inv_p = h / hsum_p
    A5 = alpha0 - 1.0 / (qf + 1.0)
    A6 = alpha0_hat - xi_inv_p
    Cppinv = (1.0 - A6 + A5) / A2
    tq3 = jnp.abs(Cppinv / (xi_inv_p * (qf + 2.0) * A5))
    if method == "ndf":
        # quasi-NDF: BDF corrector, NDF error weighting.  tq1/tq2/tq3
        # multiply the correction norms into the scaled local-error
        # estimates at orders q-1/q/q+1, so scaling them by the (< 1)
        # NDF/BDF error-constant ratio presents the smaller NDF
        # truncation constants to the error test and step selection.
        r = jnp.asarray(_NDF_RATIO, h.dtype)
        tq1 = tq1 * r[jnp.clip(q - 1, 0, LMAX)]
        tq2 = tq2 * r[jnp.clip(q, 0, LMAX)]
        tq3 = tq3 * r[jnp.clip(q + 1, 0, LMAX)]
    tq4 = NLS_COEF / tq2
    gamma = h / l[1]
    return l, (tq1, tq2, tq3, tq4, tq5), gamma


def _predict(zn, q):
    """zn <- Pascal(q) zn  (cvPredict)."""
    for k in range(1, QMAX + 1):
        for j in range(QMAX, k - 1, -1):
            upd = zn.at[j - 1].add(zn[j])
            zn = jnp.where(jnp.logical_and(k <= q, j <= q), upd, zn)
    return zn


def _unpredict(zn, q):
    """Inverse of _predict (cvRestore)."""
    for k in range(QMAX, 0, -1):
        for j in range(k, QMAX + 1):
            upd = zn.at[j - 1].add(-zn[j])
            zn = jnp.where(jnp.logical_and(k <= q, j <= q), upd, zn)
    return zn


def _rescale(zn, tau, h, q, eta):
    fac = eta
    for j in range(1, LMAX):
        upd = zn.at[j].multiply(fac)
        zn = jnp.where(j <= q, upd, zn)
        fac = fac * eta
    return zn, h * eta


def _increase_order(zn, tau, h, q, acor_save):
    """cvIncreaseBDF: add one order using the saved correction."""
    dt = zn.dtype
    l = jnp.zeros((LMAX,), dt).at[2].set(1.0)
    alpha0 = jnp.asarray(-1.0, dt)
    alpha1 = jnp.asarray(1.0, dt)
    prod = jnp.asarray(1.0, dt)
    xiold = jnp.asarray(1.0, dt)
    hsum = h
    for j in range(1, QMAX):                     # j = 1 .. q-1
        active = j <= q - 1
        hsum_n = hsum + tau[j + 1]
        xi = hsum_n / h
        prod_n = prod * xi
        alpha0_n = alpha0 - 1.0 / (j + 1)
        alpha1_n = alpha1 + 1.0 / xi
        l_n = l
        for i in range(QMAX, 1, -1):             # i = j+2 .. 2 descending
            upd = l_n.at[i].set(l_n[i] * xiold + l_n[i - 1])
            l_n = jnp.where(i <= j + 2, upd, l_n)
        hsum = jnp.where(active, hsum_n, hsum)
        prod = jnp.where(active, prod_n, prod)
        alpha0 = jnp.where(active, alpha0_n, alpha0)
        alpha1 = jnp.where(active, alpha1_n, alpha1)
        l = jnp.where(active, l_n, l)
        xiold = jnp.where(active, xi, xiold)
    A1 = (-alpha0 - alpha1) / prod
    Lrow = jnp.clip(q + 1, 2, QMAX)
    zn = zn.at[Lrow].set(A1 * acor_save)
    for j in range(2, QMAX):
        upd = zn.at[j].add(l[j] * zn[Lrow])
        zn = jnp.where(jnp.logical_and(j >= 2, j <= q), upd, zn)
    return zn


def _decrease_order(zn, tau, h, q):
    """cvDecreaseBDF: drop one order."""
    dt = zn.dtype
    l = jnp.zeros((LMAX,), dt).at[2].set(1.0)
    hsum = jnp.zeros((), dt)
    for j in range(1, QMAX - 1):                 # j = 1 .. q-2
        active = j <= q - 2
        hsum_n = hsum + tau[j]
        xi = hsum_n / h
        l_n = l
        for i in range(QMAX, 1, -1):
            upd = l_n.at[i].set(l_n[i] * xi + l_n[i - 1])
            l_n = jnp.where(i <= j + 2, upd, l_n)
        hsum = jnp.where(active, hsum_n, hsum)
        l = jnp.where(active, l_n, l)
    qrow = jnp.clip(q, 2, QMAX)
    for j in range(2, QMAX):
        upd = zn.at[j].add(-l[j] * zn[qrow])
        zn = jnp.where(jnp.logical_and(j >= 2, j < q), upd, zn)
    return zn


# --------------------------------------------------------------------------
# one integration step with retries (cvStep)
# --------------------------------------------------------------------------
def step(model, st: BDFState, t_limit, iinj, opts: BDFOptions) -> BDFState:
    """Advance one accepted BDF step, never crossing t_limit (tstop mode)."""
    st, _ = _step_impl(model, st, t_limit, iinj, opts)
    return st


def _step_impl(model, st: BDFState, t_limit, iinj, opts: BDFOptions,
               deliver=None, y_ev=None):
    """One accepted BDF step (cvStep).  Returns (state, f_first) where
    ``f_first`` is the rhs evaluation of the first Newton iteration.

    ``deliver`` (optional bool[]) rides event-delivery lanes through the
    same Newton machinery: their first rhs is evaluated at the *current*
    time on the post-event state ``y_ev`` instead of (t+h, ypred), and the
    lane converges/accepts immediately — the caller rebuilds the order-1
    reset from ``f_first`` while step lanes proceed unchanged.  With
    ``deliver=None`` the lowered computation is identical to the
    historical ``step``.
    """
    dtype = st.zn.dtype
    y_ref = st.zn[0]
    t0 = st.t
    reuse = opts.jac_policy == "reuse"

    def wrms(x, y):
        return _wrms(x, y, opts)

    def attempt_body(carry):
        st, ncf, nef, attempts, done, f_first = carry

        # ---- tstop / hmax clamp --------------------------------------------
        room = t_limit - st.t
        h_goal = jnp.minimum(st.h, jnp.minimum(room, opts.hmax))
        h_goal = jnp.maximum(h_goal, HMIN)
        eta0 = h_goal / st.h
        zn, h = _rescale(st.zn, st.tau, st.h, st.q, eta0)
        st = st._replace(zn=zn, h=h)

        l, tq, gamma = _set_bdf_coeffs(st.q, st.h, st.tau, method=opts.method)
        tq1, tq2, tq3, tq4, tq5 = tq

        zn_pred = _predict(st.zn, st.q)
        ypred = zn_pred[0]
        zdot_term = zn_pred[1] / l[1]            # gamma * ydot_pred
        t_new = st.t + st.h

        # ---- Jacobian freshness (cvNlsNewton's callSetup decision) ---------
        # Setup is hoisted OUT of the Newton loop: one assembly+factorization
        # per attempt at most (vs one per iteration on the legacy path), and
        # usually zero — the stored factors survive across accepted steps
        # until gamma drifts, MSBP steps pass, or convergence degrades.
        if reuse:
            gamrat = gamma / st.gamma_saved
            need = jnp.logical_or(
                st.jbad,
                jnp.logical_or(st.nst - st.nstlp >= MSBP,
                               jnp.abs(gamrat - 1.0) > DGMAX))
            factors = jax.lax.cond(
                need,
                lambda: model.newton_setup(ypred, gamma, mode=opts.precond),
                lambda: st.factors)
            st = st._replace(
                factors=factors,
                gamma_saved=jnp.where(need, gamma, st.gamma_saved),
                nstlp=jnp.where(need, st.nst, st.nstlp),
                nsetups=st.nsetups + need.astype(jnp.int32),
                jbad=jnp.zeros((), bool))
            jcur = need                          # factors current for this y?
        else:
            jcur = jnp.ones((), bool)            # rebuilt every iteration

        # ---- modified Newton (cvNlsNewton) ---------------------------------
        def newton_body(c):
            y, acor, delp, crate, m, conv, div, nni, nfe, f_keep = c
            if deliver is None:
                f = model.rhs(t_new, y, iinj)
            else:
                # deliver lanes share this evaluation: rhs at the current
                # time on the post-event state (exactly reinit's f)
                t_eval = jnp.where(deliver, t0, t_new)
                y_eval = jnp.where(deliver, y_ev, y)
                f = model.rhs(t_eval, y_eval, iinj)
            f_keep = jnp.where(m == 0, f, f_keep)
            G = acor + zdot_term - gamma * f
            if reuse:
                delta = model.newton_solve(st.factors, -G, mode=opts.precond)
            else:
                delta = model.solve_newton_mat(y, gamma, -G, mode=opts.precond)
            dnrm = wrms(delta, y_ref)
            y = y + delta
            acor = acor + delta
            crate_n = jnp.where(m > 0, jnp.maximum(CRDOWN * crate,
                                                   dnrm / jnp.maximum(delp, 1e-300)),
                                crate)
            dcon = dnrm * jnp.minimum(1.0, crate_n) / tq4
            conv = dcon < 1.0
            if deliver is not None:
                conv = jnp.logical_or(conv, deliver)
            div = jnp.logical_and(m >= 1, dnrm > RDIV * jnp.maximum(delp, 1e-300))
            return (y, acor, dnrm, crate_n, m + 1, conv, div, nni + 1, nfe + 1,
                    f_keep)

        def newton_cond(c):
            m, conv, div = c[4], c[5], c[6]
            return jnp.logical_and(m < MAX_NEWTON,
                                   jnp.logical_and(~conv, ~div))

        init = (ypred, jnp.zeros_like(ypred), jnp.zeros((), dtype),
                jnp.ones((), dtype), jnp.zeros((), jnp.int32),
                jnp.zeros((), bool), jnp.zeros((), bool), st.nni, st.nfe,
                f_first)
        y, acor, _, crate, m_it, conv, _, nni, nfe, f_first = jax.lax.while_loop(
            newton_cond, newton_body, init)
        nsetups = st.nsetups if reuse else st.nsetups + (nni - st.nni)
        st = st._replace(nni=nni, nfe=nfe, nsetups=nsetups)

        acnrm = wrms(acor, y_ref)
        dsm = acnrm * tq2

        err_ok = dsm <= 1.0
        accepted = jnp.logical_and(conv, err_ok)
        if deliver is not None:
            # deliver lanes terminate after one attempt; their step state
            # is discarded by the caller in favour of the order-1 reset
            accepted = jnp.logical_or(accepted, deliver)
        # stale-factor convergence failure (CVODE's CV_FAIL_BAD_J): retry
        # the SAME step with a forced fresh setup before any h reduction
        stale = (jnp.logical_and(~conv, ~jcur) if reuse
                 else jnp.zeros((), bool))

        # shared BDF1-restart evaluation: both failure ladders rebuild
        # zn[1] = h * f(t, zn[0]) on their force paths.  zn[0] and t are
        # only touched on accept so the evaluation is attempt-invariant,
        # and it almost never fires: one gated cond serves both ladders
        # instead of hoisting a rhs into every attempt
        force_ef = jnp.logical_and(jnp.logical_and(conv, ~accepted),
                                   nef + 1 >= MAX_NEF)
        force_cf = (jnp.logical_and(jnp.logical_and(~conv, jcur),
                                    ncf + 1 >= MAX_NCF_RESTART)
                    if reuse else jnp.zeros((), bool))
        f_restart = jax.lax.cond(
            jnp.logical_or(force_ef, force_cf),
            lambda: model.rhs(t0, y_ref, iinj),
            lambda: jnp.zeros_like(y_ref))

        # ---- outcomes -------------------------------------------------------
        def on_conv_fail(st, ncf, nef):
            zn = st.zn                            # zn was never predicted in-place
            if reuse:
                # stale-factor retries pass through untouched (eta = 1, no
                # counter charges) — only jbad is raised.  With fresh
                # factors the ladder shrinks by ETACF, and because it only
                # ever sees fresh factors at the *predictor*, several
                # shrinks that still cannot land the corrector restart the
                # BDF1 history outright (the netf force's twin) instead of
                # riding the shrink to MAX_NCF — the per-iteration legacy
                # rebuild recovers from garbage predictions on its own,
                # this path needs the restart
                force = jnp.logical_and(~stale, ncf + 1 >= MAX_NCF_RESTART)
                eta = jnp.where(stale, jnp.asarray(1.0, dtype),
                                jnp.asarray(ETACF, dtype))
                q = jnp.where(force, jnp.ones((), jnp.int32), st.q)
                zn, h = _rescale(zn, st.tau, st.h, q, eta)
                zn = jnp.where(force, zn.at[1].set(h * f_restart), zn)
                st = st._replace(zn=zn, h=h, q=q,
                                 etamax=jnp.where(stale, st.etamax,
                                                  jnp.asarray(1.0, dtype)),
                                 nncf=st.nncf + jnp.where(stale, 0, 1),
                                 jbad=jnp.ones((), bool),
                                 nfe=st.nfe + jnp.where(force, 1, 0))
                inc = jnp.where(stale, 0, 1)
                return st, ncf + inc, nef
            zn, h = _rescale(zn, st.tau, st.h, st.q,
                             jnp.asarray(ETACF, dtype))
            st = st._replace(zn=zn, h=h, etamax=jnp.asarray(1.0, dtype),
                             nncf=st.nncf + 1, jbad=jnp.ones((), bool))
            return st, ncf + 1, nef

        def on_err_fail(st, ncf, nef):
            Lq = (st.q + 1).astype(dtype)
            eta = 1.0 / (jnp.power(BIAS2 * dsm, 1.0 / Lq) + ADDON)
            eta = jnp.clip(eta, ETAMIN_EF, ETAMXF)
            # after many failures drop to order 1 with small steps
            force = nef + 1 >= MAX_NEF
            q = jnp.where(force, jnp.ones((), jnp.int32), st.q)
            eta = jnp.where(force, jnp.asarray(ETAMIN_EF, dtype), eta)
            zn, h = _rescale(st.zn, st.tau, st.h, q, eta)
            # when forcing q=1, zn[1] = h * f_restart (CVODE's small-NEF
            # restart): after MAX_NEF rescales the history row is no longer
            # a valid first-derivative term, so the retry would keep
            # solving a corrupted BDF1 equation
            zn = jnp.where(force, zn.at[1].set(h * f_restart), zn)
            st = st._replace(zn=zn, h=h, q=q, etamax=jnp.asarray(1.0, dtype),
                             netf=st.netf + 1,
                             nfe=st.nfe + jnp.where(force, 1, 0))
            return st, ncf, nef + 1

        def on_accept(st, ncf, nef):
            # cvCompleteStep
            q, h = st.q, st.h
            nst = st.nst + 1
            tau = st.tau
            for i in range(LMAX, 1, -1):         # cvCompleteStep: i = q .. 2
                upd = tau.at[i].set(tau[i - 1])
                tau = jnp.where(i <= q, upd, tau)
            tau = jnp.where(jnp.logical_and(q == 1, nst > 1),
                            tau.at[2].set(tau[1]), tau)
            tau = tau.at[1].set(h)
            zn = zn_pred
            for j in range(LMAX):
                upd = zn.at[j].add(l[j] * acor)
                zn = jnp.where(j <= q, upd, zn)
            qwait = st.qwait - 1

            # ---- order & step selection (cvPrepareNextStep) ----------------
            Lq = (q + 1).astype(dtype)
            etaq = 1.0 / (jnp.power(BIAS2 * dsm, 1.0 / Lq) + ADDON)

            do_sel = qwait == 0
            # eta for order q-1
            ddn = wrms(zn[jnp.clip(q, 1, QMAX)], y_ref) * tq1
            etaqm1 = jnp.where(q > 1,
                               1.0 / (jnp.power(BIAS1 * ddn, 1.0 / q.astype(dtype)) + ADDON),
                               0.0)
            # eta for order q+1
            dup = wrms(acor - st.acor_save, y_ref) * tq3
            etaqp1 = jnp.where(q < QMAX,
                               1.0 / (jnp.power(BIAS3 * dup, 1.0 / (Lq + 1.0)) + ADDON),
                               0.0)
            etam = jnp.maximum(etaqm1, jnp.maximum(etaq, etaqp1))
            qprime_sel = jnp.where(etam == etaqm1, q - 1,
                                   jnp.where(etam == etaq, q, q + 1))
            eta_sel = jnp.where(etam < THRESH, 1.0, etam)
            qprime_sel = jnp.where(etam < THRESH, q, qprime_sel)

            eta_noq = jnp.where(etaq < THRESH, 1.0, etaq)
            eta = jnp.where(do_sel, eta_sel, eta_noq)
            qprime = jnp.where(do_sel, qprime_sel, q)
            eta = jnp.minimum(eta, st.etamax)
            # never exceed hmax
            eta = eta / jnp.maximum(1.0, eta * h / opts.hmax)

            # apply order change on zn
            zn_inc = _increase_order(zn, tau, h, q, acor)
            zn_dec = _decrease_order(zn, tau, h, q)
            zn = jnp.where(qprime > q, zn_inc, jnp.where(qprime < q, zn_dec, zn))
            qnew = jnp.clip(qprime, 1, QMAX)
            zn, hnew = _rescale(zn, tau, h, qnew, eta)
            qwait = jnp.where(do_sel, qnew + 1, qwait)

            # convergence-rate decay: a multi-iteration Newton whose
            # contraction rate exceeds the CRDOWN slack flags the factors
            # stale so the next step rebuilds (single-iteration solves keep
            # the init crate=1 and carry no rate information)
            jbad_next = jnp.logical_and(m_it >= 2, crate > CRDOWN)
            st = st._replace(
                t=st.t + h, h=hnew, q=qnew, zn=zn, tau=tau, qwait=qwait,
                etamax=jnp.asarray(ETAMX, dtype), acor_save=acor, nst=nst,
                jbad=jnp.logical_or(st.jbad, jbad_next))
            return st, ncf, nef

        st_cf, ncf_cf, nef_cf = on_conv_fail(st, ncf, nef)
        st_ef, ncf_ef, nef_ef = on_err_fail(st, ncf, nef)
        st_ok, ncf_ok, nef_ok = on_accept(st, ncf, nef)

        st = jax.tree_util.tree_map(
            lambda a, b, c: jnp.where(accepted, a, jnp.where(conv, b, c)),
            st_ok, st_ef, st_cf)
        ncf = jnp.where(accepted, ncf_ok, jnp.where(conv, ncf_ef, ncf_cf))
        nef = jnp.where(accepted, nef_ok, jnp.where(conv, nef_ef, nef_cf))

        give_up = jnp.logical_or(ncf >= MAX_NCF,
                                 jnp.logical_or(nef >= MAX_NEF + 3,
                                                attempts + 1 >= MAX_ATTEMPTS))
        if deliver is not None:
            give_up = jnp.logical_and(give_up, ~deliver)
        st = st._replace(failed=jnp.logical_or(st.failed, give_up))
        done = jnp.logical_or(accepted, give_up)
        return st, ncf, nef, attempts + 1, done, f_first

    def attempt_cond(carry):
        return ~carry[4]

    z32 = jnp.zeros((), jnp.int32)
    st, _, _, _, _, f_first = jax.lax.while_loop(
        attempt_cond, attempt_body,
        (st, z32, z32, z32, jnp.zeros((), bool), jnp.zeros_like(y_ref)))
    # snap to t_limit when within rounding distance
    snap = (t_limit - st.t) < 1e-10
    st = st._replace(t=jnp.where(snap, t_limit, st.t))
    return st, f_first


def step_or_deliver(model, st: BDFState, t_limit, w_ampa, w_gaba, deliver,
                    iinj, opts: BDFOptions) -> BDFState:
    """Fused branch of the vardt advance loop: one rhs + Hines-solve stream
    serves both the event-delivery reset and the BDF step.

    ``deliver`` (bool[]) selects per lane: True -> apply the synaptic
    discontinuity at the current time and reset the IVP (order 1, fresh h,
    history discarded — ``deliver_event`` semantics, bit-identical);
    False -> one accepted BDF step clamped at ``t_limit`` (``step``
    semantics, bit-identical).  The deliver lanes ride the step's Newton
    machinery so the reset's rhs evaluation is the corrector's first —
    under vmap the advance loop pays ONE evaluation stream per iteration
    instead of one per branch.
    """
    y_ev = model.apply_event(st.zn[0], w_ampa, w_gaba)
    st_stepped, f_ev = _step_impl(model, st, t_limit, iinj, opts,
                                  deliver=deliver, y_ev=y_ev)
    counters = (st.nst, st.nfe + 1, st.nni, st.netf, st.nncf, st.nreset + 1,
                st.nsetups)
    st_del = reinit(model, st.t, y_ev, iinj, opts, counters=counters, f=f_ev)
    st_del = st_del._replace(failed=st.failed)
    return jax.tree_util.tree_map(
        lambda d, s: jnp.where(deliver, d, s), st_del, st_stepped)


def advance_to(model, st: BDFState, t_target, iinj, opts: BDFOptions,
               max_steps: int = 100000) -> BDFState:
    """Step until st.t >= t_target (or failure)."""

    def cond(c):
        st, k = c
        return jnp.logical_and(jnp.logical_and(st.t < t_target - 1e-12, ~st.failed),
                               k < max_steps)

    def body(c):
        st, k = c
        return step(model, st, t_target, iinj, opts), k + 1

    st, _ = jax.lax.while_loop(cond, body, (st, jnp.zeros((), jnp.int32)))
    return st


def interpolate(st: BDFState, t_eval):
    """Evaluate the Nordsieck polynomial at t_eval in [t-h_used, t]."""
    s = (t_eval - st.t) / st.h
    y = jnp.zeros_like(st.zn[0])
    sj = jnp.ones(())
    for j in range(LMAX):
        y = y + jnp.where(j <= st.q, sj, 0.0) * st.zn[j]
        sj = sj * s
    return y


def deliver_event(model, st: BDFState, w_ampa, w_gaba, iinj,
                  opts: BDFOptions) -> BDFState:
    """Apply a synaptic discontinuity at the current time and reset the IVP
    (paper §2.3: discontinuities lead to a reset of the IVP problem and
    interpolator state history)."""
    y = model.apply_event(st.zn[0], w_ampa, w_gaba)
    counters = (st.nst, st.nfe + 1, st.nni, st.netf, st.nncf, st.nreset + 1,
                st.nsetups)
    new = reinit(model, st.t, y, iinj, opts, counters=counters)
    new = new._replace(failed=st.failed)
    return new

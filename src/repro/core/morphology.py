"""Compartmental morphologies in Hines ordering.

A neuron morphology is spatially discretised into a tree of cylindrical
compartments (paper §2.1, Fig. 2b).  We store the tree in *Hines order*:
``parent[i] < i`` for every non-root compartment, root (soma) at index 0.
This ordering makes the quasi-tridiagonal membrane system solvable in O(C)
with one backward elimination + one forward substitution (Hines 1984), and
is the layout the Pallas kernel consumes.

Units used throughout ``repro.core`` (NEURON-compatible):
  voltage mV, time ms, capacitance nF, conductance uS, current nA,
  lengths um, specific capacitance uF/cm^2, specific conductance S/cm^2,
  axial resistivity Ohm*cm.
With these, ``1 nA / 1 nF = 1 mV/ms`` and ``1 uS * 1 mV = 1 nA``.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import numpy as np


class Morphology(NamedTuple):
    """Static description of one compartmental tree (numpy, not traced)."""

    parent: np.ndarray      # int32[C]; parent[0] == -1; parent[i] < i
    length: np.ndarray      # f64[C] um
    diam: np.ndarray        # f64[C] um
    area: np.ndarray        # f64[C] um^2 (membrane area, pi*d*L)
    cap: np.ndarray         # f64[C] nF   (cm * area)
    g_axial: np.ndarray     # f64[C] uS   (conductance to parent; 0 for root)

    @property
    def n_comp(self) -> int:
        return int(self.parent.shape[0])


_CM_UF_PER_CM2 = 1.0       # specific membrane capacitance
_RA_OHM_CM = 100.0         # axial resistivity


def _finalize(parent, length, diam) -> Morphology:
    parent = np.asarray(parent, np.int32)
    length = np.asarray(length, np.float64)
    diam = np.asarray(diam, np.float64)
    c = parent.shape[0]
    if c == 0:
        raise ValueError("empty morphology")
    if parent[0] != -1:
        raise ValueError("root must be compartment 0")
    if not np.all(parent[1:] < np.arange(1, c)):
        raise ValueError("not in Hines order (need parent[i] < i)")
    area = math.pi * diam * length                          # um^2
    # cm [uF/cm^2] * area [um^2] -> nF:  1 um^2 = 1e-8 cm^2; 1 uF = 1e3 nF
    cap = _CM_UF_PER_CM2 * area * 1e-8 * 1e3                # nF
    # axial resistance between compartment centres, child i <-> parent p:
    #   R = Ra * (L_i/2)/(pi d_i^2/4) + Ra * (L_p/2)/(pi d_p^2/4)   [Ohm*cm * um/um^2]
    # convert: Ra [Ohm*cm] * L [um] / A [um^2] = Ra * 1e4 * (L/A) [Ohm] (1 cm = 1e4 um)
    g_ax = np.zeros(c, np.float64)
    for i in range(1, c):
        p = parent[i]
        r_i = _RA_OHM_CM * 1e4 * (length[i] / 2.0) / (math.pi * diam[i] ** 2 / 4.0)
        r_p = _RA_OHM_CM * 1e4 * (length[p] / 2.0) / (math.pi * diam[p] ** 2 / 4.0)
        r_ohm = r_i + r_p
        g_ax[i] = 1e6 / r_ohm                               # Ohm -> uS (1/Ohm = 1e6 uS)
    return Morphology(parent, length, diam, area, cap, g_ax)


def soma_only(diam: float = 20.0) -> Morphology:
    """Single isopotential compartment (classic HH point soma)."""
    return _finalize([-1], [diam], [diam])


def ball_and_stick(n_dend: int = 10, dend_len: float = 50.0,
                   dend_diam: float = 2.0, soma_diam: float = 20.0) -> Morphology:
    """Soma + one unbranched dendrite of ``n_dend`` compartments."""
    parent = [-1] + list(range(0, n_dend))
    length = [soma_diam] + [dend_len] * n_dend
    diam = [soma_diam] + [dend_diam] * n_dend
    return _finalize(parent, length, diam)


def branched_tree(depth: int = 3, seg_per_branch: int = 2,
                  soma_diam: float = 20.0, trunk_diam: float = 3.0,
                  taper: float = 0.7, seg_len: float = 40.0) -> Morphology:
    """Synthetic binary dendritic tree: at each level the branch bifurcates and
    its diameter tapers.  Models the layer-5-pyramidal-like arborisation used
    in the paper's single-cell experiments (L5_TTPC2) at configurable size.
    Emitted in Hines order by construction (BFS)."""
    parent = [-1]
    length = [soma_diam]
    diam = [soma_diam]
    frontier = [(0, trunk_diam)]                 # (attach index, diameter)
    for _level in range(depth):
        nxt = []
        for attach, d in frontier:
            for _child in range(2):
                prev = attach
                for _s in range(seg_per_branch):
                    parent.append(prev)
                    length.append(seg_len)
                    diam.append(d)
                    prev = len(parent) - 1
                nxt.append((prev, d * taper))
        frontier = nxt
    return _finalize(parent, length, diam)


def by_name(name: str, **kw) -> Morphology:
    if name == "soma":
        return soma_only(**kw)
    if name == "ball_and_stick":
        return ball_and_stick(**kw)
    if name == "branched":
        return branched_tree(**kw)
    raise ValueError(f"unknown morphology {name!r}")

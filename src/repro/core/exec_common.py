"""Shared pieces of the execution models: spike detection, synaptic fan-out,
batched state initialisation and device-side network arrays."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core.cell import CellModel
from repro.core.network import Network

SPIKE_THR = -20.0     # mV upward crossing at the soma


class DeviceNet(NamedTuple):
    pre: jnp.ndarray
    post: jnp.ndarray
    delay: jnp.ndarray
    w_ampa: jnp.ndarray
    w_gaba: jnp.ndarray


def to_device(net: Network) -> DeviceNet:
    return DeviceNet(jnp.asarray(net.pre), jnp.asarray(net.post),
                     jnp.asarray(net.delay), jnp.asarray(net.w_ampa),
                     jnp.asarray(net.w_gaba))


def batch_init(model: CellModel, n: int, v0: float = -65.0):
    y = model.init_state(v0)
    return jnp.tile(y[None, :], (n, 1))


def detect_spikes(v_prev, v_new, t_prev, t_new):
    """Upward threshold crossing; spike time by linear interpolation.

    All inputs broadcastable over neurons. Returns (spiked bool[N], t_spike[N]).
    """
    crossed = jnp.logical_and(v_prev <= SPIKE_THR, v_new > SPIKE_THR)
    frac = (SPIKE_THR - v_prev) / jnp.where(v_new == v_prev, 1.0, v_new - v_prev)
    t_spike = t_prev + frac * (t_new - t_prev)
    return crossed, jnp.where(crossed, t_spike, 0.0)


def fanout(dnet: DeviceNet, spiked, t_spike):
    """Edge-parallel synaptic fan-out of one spike per neuron.

    Returns candidate events (target, t_ev, w_ampa, w_gaba, valid), length E.
    """
    valid = spiked[dnet.pre]
    t_ev = t_spike[dnet.pre] + dnet.delay
    return dnet.post, t_ev, dnet.w_ampa, dnet.w_gaba, valid


def horizon_times(dnet: DeviceNet, n: int, t_clock, t_end):
    """FAP dependency horizon: t_max[i] = min over in-edges (t[pre]+delay).

    This is the SPMD realisation of the paper's stepping-notification map
    (DESIGN.md §3): a scatter-min over the static edge list.
    Neurons without in-edges get t_end.
    """
    cand = t_clock[dnet.pre] + dnet.delay
    hor = jnp.full((n,), t_end, t_clock.dtype).at[dnet.post].min(cand)
    return jnp.minimum(hor, t_end)


def spike_rates(rec: ev.SpikeRecord, t_lo: float, t_hi: float):
    """Per-neuron firing rate (Hz) in a window; times in ms."""
    m = jnp.logical_and(rec.times >= t_lo, rec.times < t_hi)
    return m.sum(axis=1) / ((t_hi - t_lo) * 1e-3)

"""Shared pieces of the execution models: spike detection, synaptic fan-out,
batched state initialisation and device-side network arrays."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core.cell import CellModel
from repro.core.network import Network

SPIKE_THR = -20.0     # mV upward crossing at the soma


class DeviceNet(NamedTuple):
    pre: jnp.ndarray
    post: jnp.ndarray
    delay: jnp.ndarray
    w_ampa: jnp.ndarray
    w_gaba: jnp.ndarray


def to_device(net: Network) -> DeviceNet:
    return DeviceNet(jnp.asarray(net.pre), jnp.asarray(net.post),
                     jnp.asarray(net.delay), jnp.asarray(net.w_ampa),
                     jnp.asarray(net.w_gaba))


def batch_init(model: CellModel, n: int, v0: float = -65.0):
    y = model.init_state(v0)
    return jnp.tile(y[None, :], (n, 1))


def detect_spikes(v_prev, v_new, t_prev, t_new):
    """Upward threshold crossing; spike time by linear interpolation.

    All inputs broadcastable over neurons. Returns (spiked bool[N], t_spike[N]).
    """
    crossed = jnp.logical_and(v_prev <= SPIKE_THR, v_new > SPIKE_THR)
    frac = (SPIKE_THR - v_prev) / jnp.where(v_new == v_prev, 1.0, v_new - v_prev)
    t_spike = t_prev + frac * (t_new - t_prev)
    return crossed, jnp.where(crossed, t_spike, 0.0)


def fanout(dnet: DeviceNet, spiked, t_spike):
    """Edge-parallel synaptic fan-out of one spike per neuron.

    Returns candidate events (target, t_ev, w_ampa, w_gaba, valid), length E.
    """
    valid = spiked[dnet.pre]
    t_ev = t_spike[dnet.pre] + dnet.delay
    return dnet.post, t_ev, dnet.w_ampa, dnet.w_gaba, valid


def horizon_times(dnet: DeviceNet, n: int, t_clock, t_end, *,
                  t_table=None, horizon_cap=None):
    """FAP dependency horizon: t_max[i] = min over in-edges (t[pre]+delay).

    This is the SPMD realisation of the paper's stepping-notification map
    (DESIGN.md §3): a scatter-min over the static edge list.
    Neurons without in-edges get t_end.

    The same helper serves the shard-local SPMD round (the notify -> horizon
    stage decomposition of ``distributed/fap_spmd``), which passes a
    ``dnet`` holding the shard's local edge slice with the *shard-relative*
    post index in ``dnet.post``:
      t_table: optional clock table indexed by ``dnet.pre`` when pre ids are
               global but ``t_clock`` is shard-local (the transport's notify
               output); defaults to ``t_clock`` itself,
      horizon_cap: optional per-round advancement bound (ms) folded in here
               so every execution model clamps identically.
    """
    tt = t_clock if t_table is None else t_table
    cand = tt[dnet.pre] + dnet.delay
    hor = jnp.full((n,), t_end, t_clock.dtype).at[dnet.post].min(cand)
    hor = jnp.minimum(hor, t_end)
    if horizon_cap is not None:
        hor = jnp.minimum(hor, t_clock + horizon_cap)
    return hor


def runnable_mask(t_clock, horizon, eps: float = 1e-12):
    """A neuron is runnable when strictly behind its dependency horizon."""
    return t_clock < horizon - eps


def spike_rates(rec: ev.SpikeRecord, t_lo: float, t_hi: float):
    """Per-neuron firing rate (Hz) in a window; times in ms."""
    m = jnp.logical_and(rec.times >= t_lo, rec.times < t_hi)
    return m.sum(axis=1) / ((t_hi - t_lo) * 1e-3)

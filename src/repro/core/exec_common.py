"""Shared pieces of the execution models: spike detection, synaptic fan-out,
batched state initialisation and device-side network arrays."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core.cell import CellModel
from repro.core.network import Network

SPIKE_THR = -20.0     # mV upward crossing at the soma


class DeviceNet(NamedTuple):
    pre: jnp.ndarray
    post: jnp.ndarray
    delay: jnp.ndarray
    w_ampa: jnp.ndarray
    w_gaba: jnp.ndarray


def to_device(net: Network) -> DeviceNet:
    return DeviceNet(jnp.asarray(net.pre), jnp.asarray(net.post),
                     jnp.asarray(net.delay), jnp.asarray(net.w_ampa),
                     jnp.asarray(net.w_gaba))


def batch_init(model: CellModel, n: int, v0: float = -65.0):
    y = model.init_state(v0)
    return jnp.tile(y[None, :], (n, 1))


def detect_spikes(v_prev, v_new, t_prev, t_new):
    """Upward threshold crossing; spike time by linear interpolation.

    All inputs broadcastable over neurons. Returns (spiked bool[N], t_spike[N]).
    """
    crossed = jnp.logical_and(v_prev <= SPIKE_THR, v_new > SPIKE_THR)
    frac = (SPIKE_THR - v_prev) / jnp.where(v_new == v_prev, 1.0, v_new - v_prev)
    t_spike = t_prev + frac * (t_new - t_prev)
    return crossed, jnp.where(crossed, t_spike, 0.0)


def fanout_edges(dnet: DeviceNet, spiked, t_spike):
    """Edge-parallel synaptic fan-out of one spike per neuron.

    Returns candidate events (target, t_ev, w_ampa, w_gaba, valid), length E.
    """
    valid = spiked[dnet.pre]
    t_ev = t_spike[dnet.pre] + dnet.delay
    return dnet.post, t_ev, dnet.w_ampa, dnet.w_gaba, valid


fanout = fanout_edges     # historical name (shadowed by the knob in factories)


def horizon_times(dnet: DeviceNet, n: int, t_clock, t_end, *,
                  t_table=None, horizon_cap=None):
    """FAP dependency horizon: t_max[i] = min over in-edges (t[pre]+delay).

    This is the SPMD realisation of the paper's stepping-notification map
    (DESIGN.md §3): a scatter-min over the static edge list.
    Neurons without in-edges get t_end.

    The same helper serves the shard-local SPMD round (the notify -> horizon
    stage decomposition of ``distributed/fap_spmd``), which passes a
    ``dnet`` holding the shard's local edge slice with the *shard-relative*
    post index in ``dnet.post``:
      t_table: optional clock table indexed by ``dnet.pre`` when pre ids are
               global but ``t_clock`` is shard-local (the transport's notify
               output); defaults to ``t_clock`` itself,
      horizon_cap: optional per-round advancement bound (ms) folded in here
               so every execution model clamps identically.
    """
    tt = t_clock if t_table is None else t_table
    cand = tt[dnet.pre] + dnet.delay
    hor = jnp.full((n,), t_end, t_clock.dtype).at[dnet.post].min(cand)
    hor = jnp.minimum(hor, t_end)
    if horizon_cap is not None:
        hor = jnp.minimum(hor, t_clock + horizon_cap)
    return hor


def runnable_mask(t_clock, horizon, eps: float = 1e-12):
    """A neuron is runnable when strictly behind its dependency horizon."""
    return t_clock < horizon - eps


# ---------------------------------------------------------------------------
# active-set compaction: step only the runnable frontier
# ---------------------------------------------------------------------------
class SchedStats(NamedTuple):
    """Per-run active-set telemetry accumulated over scheduler rounds.

    ``runnable`` sums the runnable-frontier size offered to the stepper
    each round; ``stepped`` the lanes that actually advanced; ``lanes``
    the lanes *dispatched* (vmap width: N on the dense path, ``batch_cap``
    per compact dispatch).  ``stepped / lanes`` is the realized batch
    occupancy and ``1 - stepped / lanes`` the wasted-lane fraction — the
    dense-masking overhead the compact path removes.
    """
    runnable: jnp.ndarray      # i64[] sum over rounds of runnable lanes
    stepped: jnp.ndarray       # i64[] sum of lanes that actually advanced
    lanes: jnp.ndarray         # i64[] sum of lanes dispatched to the stepper
    rounds: jnp.ndarray        # i32[] scheduler rounds (compact: dispatches)

    @staticmethod
    def zeros() -> "SchedStats":
        z64 = jnp.zeros((), jnp.int64)
        return SchedStats(z64, z64, z64, jnp.zeros((), jnp.int32))


def sched_metrics(stats: SchedStats) -> dict:
    """Host-side summary of ``SchedStats`` (floats, safe for zero rounds)."""
    lanes = max(1, int(stats.lanes))
    return {
        "rounds": int(stats.rounds),
        "runnable_per_round": float(stats.runnable) / max(1, int(stats.rounds)),
        "occupancy": float(stats.stepped) / lanes,
        "wasted_lane_frac": 1.0 - float(stats.stepped) / lanes,
    }


def select_active(runnable, t_clock, cap: int, n_iters: int = 48):
    """Earliest-``cap`` restriction of the runnable frontier, sort-free.

    When more than ``cap`` neurons are runnable, keep those with the
    smallest clocks (``select_threshold`` bisection on counts — the same
    machinery as the explicit-scheduler ``k_select``); with ``cap`` or
    fewer runnable the mask is returned unchanged (the threshold lands on
    the maximum finite score).  The globally earliest runnable neuron is
    always kept, so the conservative-lookahead progress argument of
    ``exec_fap`` holds under any cap; clock ties beyond ``cap`` are
    resolved by the compaction's index order and simply roll to a later
    dispatch.
    """
    from repro.kernels.event_wheel import ops as ew_ops
    score = jnp.where(runnable, t_clock, jnp.inf)
    tau = ew_ops.select_threshold(score, cap, n_iters=n_iters)
    return jnp.logical_and(runnable, score <= tau)


def out_tables(net):
    """Host-side by-pre grouping of the static edge list: per neuron i the
    postsynaptic targets (sentinel N) and edge-list positions (sentinel E)
    of its out-edges, padded to the max out-degree.  Builders that need
    both tables (incremental horizon + compact fan-out) call this once —
    the O(E log E) grouping is the expensive part, not the two views."""
    pre = np.asarray(net.pre)
    post = np.asarray(net.post)
    n, E = int(net.n), int(pre.shape[0])
    deg = np.bincount(pre, minlength=n)
    mo = int(deg.max()) if E else 1
    order = np.argsort(pre, kind="stable")
    starts = np.zeros(n + 1, np.int64)
    starts[1:] = np.cumsum(deg)
    rank_in_pre = np.arange(E) - starts[pre[order]]
    post_t = np.full((n, mo), n, np.int32)
    post_t[pre[order], rank_in_pre] = post[order]
    edge_t = np.full((n, mo), E, np.int32)
    edge_t[pre[order], rank_in_pre] = order
    return post_t, edge_t


def out_post_table(net) -> np.ndarray:
    """Host-side static out-neighbour table: row i lists the postsynaptic
    targets of neuron i's out-edges, padded with the sentinel N.

    The compact FAP round uses it for *incremental* horizon maintenance:
    when only the [batch_cap] advanced lanes moved, the only horizon rows
    that can change are their out-neighbours (plus the lanes' own
    clock-cap terms) — O(cap * max_out_degree) per round instead of the
    O(E) full scatter-min.
    """
    return out_tables(net)[0]


def out_edge_table(net) -> np.ndarray:
    """Host-side static out-*edge* table: row i lists the edge-list
    positions of neuron i's out-edges, padded with the sentinel E.

    The compact fan-out path (``fanout="compact"``) gathers these rows for
    the <= spike_cap spiking lanes and inserts only that
    [spike_cap, max_out_degree] edge batch — O(spikes * k_out) per
    spiking round instead of the O(E) full fan-out.
    """
    return out_tables(net)[1]


def make_spike_insert(net, dnet: DeviceNet, qops, qinsert,
                      fanout: str = "dense", spike_cap: int = 256,
                      edge_table=None):
    """The fan-out + insert stage of every execution model, behind the
    ``fanout="dense"|"compact"`` knob.  Returns ``fn(eq, spiked[N],
    t_spike[N]) -> eq`` (at most one spike per neuron per call, the
    invariant all runners already hold).

    ``dense``   — the reference path: edge-parallel ``fanout`` over all E
                  edges + the net's best insert (``sched.edge_insert``).
    ``compact`` — activity-proportional delivery: when at most
                  ``spike_cap`` lanes spiked, compact the mask and gather
                  only those lanes' out-edges (``out_edge_table`` rows via
                  the ``compact_gather`` kernel), then insert the fixed
                  [spike_cap * k_out] batch through the queue's flat
                  batch insert.  More spikes than ``spike_cap`` fall back
                  to the dense branch under ``lax.cond`` — identical
                  event set either way (overflow *falls back*, never
                  drops).  Spike-free rounds insert nothing on either
                  branch, so callers may still guard with their own cond.
    """
    if fanout not in ("dense", "compact"):
        raise ValueError(f"unknown fanout mode {fanout!r}")

    def dense_ins(eq, spiked, t_sp):
        tgt, t_ev, wa, wg, valid = fanout_edges(dnet, spiked, t_sp)
        return qinsert(eq, tgt, t_ev, wa, wg, valid)

    if fanout == "dense":
        return dense_ins

    from repro.kernels.event_wheel import ops as ew_ops
    n, E = int(net.n), int(dnet.pre.shape[0])
    cap = min(int(spike_cap), n) if spike_cap > 0 else min(n, 256)
    # [N, MO], sentinel E (edge_table lets builders that also need the
    # out-post table share one out_tables() grouping pass)
    edge_tbl = jnp.asarray(out_edge_table(net) if edge_table is None
                           else edge_table)

    def compact_ins(eq, spiked, t_sp):
        ids, eids, _ = ew_ops.compact_gather(spiked, edge_tbl, cap, fill=E)
        idc = jnp.minimum(ids, n - 1)
        ok = jnp.logical_and((ids < n)[:, None], eids < E)  # [cap, MO]
        eidc = jnp.minimum(eids, E - 1)
        tgt = dnet.post[eidc]
        t_ev = t_sp[idc][:, None] + dnet.delay[eidc]
        return qops.insert_batch(eq, tgt.ravel(), t_ev.ravel(),
                                 dnet.w_ampa[eidc].ravel(),
                                 dnet.w_gaba[eidc].ravel(), ok.ravel())

    def ins(eq, spiked, t_sp):
        return jax.lax.cond(spiked.sum() <= cap, compact_ins, dense_ins,
                            eq, spiked, t_sp)

    return ins


def auto_batch_cap(stats: SchedStats, n: int, *, slack: float = 2.0,
                   floor: int = 32) -> int:
    """Pick a ``batch_cap`` from measured frontier occupancy
    (``RunResult.sched`` telemetry of a probe run): the mean per-round
    runnable frontier times ``slack`` headroom, rounded up to a power of
    two, clipped to [floor, n].  Zero-round telemetry returns ``floor``.
    """
    rounds = max(1, int(stats.rounds))
    mean_frontier = float(stats.runnable) / rounds
    want = max(float(floor), slack * mean_frontier)
    return min(n, 1 << max(0, int(np.ceil(np.log2(want)))))


def solver_stats(sts) -> dict:
    """Summed per-lane BDF counters for ``RunResult.solver`` (jit-safe:
    a dict of scalar arrays).  ``nsetups / nni`` is the Jacobian-reuse
    ratio of the freshness policy (1.0 on the legacy
    ``jac_policy="iteration"`` path, well under 0.5 under reuse)."""
    return {"nst": sts.nst.sum(), "nni": sts.nni.sum(),
            "nfe": sts.nfe.sum(), "nsetups": sts.nsetups.sum(),
            "netf": sts.netf.sum(), "nncf": sts.nncf.sum(),
            "nreset": sts.nreset.sum()}


def auto_spike_cap(rec, stats: SchedStats, n: int, *, slack: float = 4.0,
                   floor: int = 16) -> int:
    """Pick a ``spike_cap`` from measured spike-rate telemetry (the
    ``RunResult.rec`` / ``.sched`` of a probe run), mirroring
    ``auto_batch_cap``: mean spikes per scheduler round times ``slack``
    headroom, rounded up to a power of two, clipped to [floor, n].

    Spiking is burstier than the runnable frontier, hence the larger
    default slack — and undershooting is safe either way: a round with
    more than ``spike_cap`` spikes falls back to the dense fan-out branch
    (identical events, never a drop), it just stops being compact.
    """
    rounds = max(1, int(stats.rounds))
    mean_spikes = float(np.asarray(rec.count).sum()) / rounds
    want = max(float(floor), slack * mean_spikes)
    return min(n, 1 << max(0, int(np.ceil(np.log2(want)))))


def compact_frontier(runnable, t_clock, cap: int, n_iters: int = 48):
    """Select + compact the runnable frontier into a [cap] gather-id batch.

    Returns (ids i32[cap] — unique lane ids, sentinel N for empty slots;
    count i32 — selected lanes, may exceed cap when the frontier
    overflows: the overflow rolls to a later dispatch).  When the cap
    binds, the earliest-clock lanes are kept (``select_active``) and the
    globally earliest runnable lane is *force-included*: bisection-
    resolution clock ties can otherwise crowd the frontier head out of
    the index-ordered compaction, which would starve the one neuron the
    conservative-lookahead progress argument depends on.
    """
    from repro.kernels.event_wheel import ops as ew_ops
    n = t_clock.shape[0]
    sel = select_active(runnable, t_clock, cap, n_iters) if cap < n \
        else runnable
    ids, cnt = ew_ops.compact_ids(sel, cap)
    if cap < n:
        score = jnp.where(runnable, t_clock, jnp.inf)
        earliest = jnp.argmin(score).astype(ids.dtype)
        have = jnp.logical_or((ids == earliest).any(), ~runnable.any())
        last = jnp.maximum(jnp.minimum(cnt, cap) - 1, 0)
        ids = jnp.where(have, ids, ids.at[last].set(earliest))
    return ids, cnt


def gather_lanes(sts, ids_clipped):
    """Gather the per-neuron pytree rows of a compacted id list."""
    return jax.tree_util.tree_map(lambda x: x[ids_clipped], sts)


def unique_pad_ids(ids, n: int):
    """Remap sentinel padding (>= n) to distinct out-of-range ids so the
    scatters can claim ``unique_indices`` — without it XLA's duplicate-safe
    sequential scatter path dominates the compact round's cost."""
    pad = n + jnp.arange(ids.shape[0], dtype=ids.dtype)
    return jnp.where(ids < n, ids, pad)


def scatter_at(full, ids, vals):
    """``full.at[ids].set(vals)`` for a compacted id list: sentinel padding
    (>= N) is dropped and the write claims ``unique_indices`` via
    ``unique_pad_ids`` — the batch -> full-width store every compact path
    shares (single arrays here, pytrees via ``scatter_lanes``)."""
    ids_u = unique_pad_ids(ids, full.shape[0])
    return full.at[ids_u].set(vals, mode="drop", unique_indices=True)


def scatter_lanes(full, batch, ids):
    """Scatter advanced lanes back; sentinel ids (>= N) are dropped.

    ``ids`` must hold unique in-range entries (the compaction guarantees
    it) — the write is issued with ``unique_indices=True``.
    """
    n = jax.tree_util.tree_leaves(full)[0].shape[0]
    ids_u = unique_pad_ids(ids, n)
    return jax.tree_util.tree_map(
        lambda f, b: f.at[ids_u].set(b, mode="drop", unique_indices=True),
        full, batch)


def spike_rates(rec: ev.SpikeRecord, t_lo: float, t_hi: float):
    """Per-neuron firing rate (Hz) in a window; times in ms."""
    m = jnp.logical_and(rec.times >= t_lo, rec.times < t_hi)
    return m.sum(axis=1) / ((t_hi - t_lo) * 1e-3)


# ---------------------------------------------------------------------------
# preemption tolerance: round-boundary checkpoint/restore, fault injection
# and the detected-never-silent health watchdog shared by every vardt
# driver (single-host exec_fap/exec_bsp and the SPMD run_fap_spmd loop)
# ---------------------------------------------------------------------------
class SimCarry(NamedTuple):
    """The full round-boundary state of a vardt run — everything the next
    scheduler round reads, as ONE pytree so ``repro.checkpoint`` can save
    and restore it leaf-for-leaf.  Resume from a ``SimCarry`` snapshot is
    event-for-event identical to the uninterrupted run: same BDF history
    (``sts`` is the batched ``bdf.BDFState`` including the Jacobian-cache
    fields ``gamma_saved``/``nstlp``/``factors``), same pending events
    (``eq`` is the queue pytree — dense ``EventQueue``, ``WheelQueue`` or
    the SPMD round's raw ``(t, w_ampa, w_gaba)`` planes), same spike-record
    cursor (``rec``) and the same solver/sched/comm counters.

    ``hcarry`` holds the incremental-horizon carry (horizon, previous
    boundary clocks, moved ids) where a runner maintains one — the only
    mesh-shape-*dependent* leaves.  On elastic resume onto a different
    mesh shape ``restore_sim_checkpoint`` skips exactly these and the
    caller reseeds them from the restored clocks (a full recompute, which
    the incremental scheme equals bitwise because min is exact).
    """
    sts: object        # batched bdf.BDFState pytree [N, ...]
    eq: object         # queue pytree (EventQueue / WheelQueue / (t, wa, wg))
    rec: object        # ev.SpikeRecord (times + per-neuron cursor + overflow)
    hcarry: tuple      # incremental-horizon carry (possibly empty)
    counters: object   # dict of scalar telemetry (n_ev/n_rs/rounds/stats/...)


def empty_health(watchdog: bool = True) -> dict:
    """The ``RunResult.health`` record every checkpointed driver fills.

    Degradations follow the repo-wide detected-never-silent contract:
    every rollback, regression, violation and drop is counted here, and
    ``rollback_exhausted`` escalates to ``RunResult.failed``.
    """
    return {
        "watchdog": bool(watchdog),
        "checks": 0,                 # rounds the watchdog inspected
        "nonfinite_rounds": 0,       # rounds with a non-finite lane detected
        "clock_regressions": 0,      # lanes whose clock moved backwards
        "horizon_violations": 0,     # carried horizon past clock + cap
        "rollbacks": 0,              # quarantine-and-rollback events
        "rollback_exhausted": False,  # bounded retries spent -> failed
        "checkpoints_saved": 0,
        "resumed_from": None,        # round the run resumed at (or None)
        "elastic_reseeded": False,   # hcarry reseeded on mesh-shape change
        "dropped_events": 0,         # queue + parcel overflow (escalated)
        "straggler": None,           # StragglerMonitor.stats()
    }


def health_check(sts, t_prev, horizon=None, horizon_cap=None,
                 eps: float = 1e-9) -> dict:
    """Cheap per-round finiteness/invariant check (jit-safe scalars).

    * a lane is non-finite when any of its BDF history ``zn``, clock ``t``
      or step size ``h`` stopped being finite — the poisoned state that
      would otherwise propagate through parcels to the whole network,
    * clocks must be monotone: ``t`` never moves behind the previous
      round's clock (FAP lanes only ever advance),
    * a carried dependency horizon can never exceed its lane's clock by
      more than ``horizon_cap`` (the per-round advancement clamp) — drift
      here means the incremental maintenance went stale.
    """
    lane_bad = jnp.logical_or(
        ~jnp.isfinite(sts.zn).all(axis=tuple(range(1, sts.zn.ndim))),
        jnp.logical_or(~jnp.isfinite(sts.t), ~jnp.isfinite(sts.h)))
    out = {
        "nonfinite_lanes": lane_bad.sum(dtype=jnp.int32),
        "clock_regress": (sts.t < t_prev - eps).sum(dtype=jnp.int32),
    }
    if horizon is not None and horizon_cap is not None:
        out["horizon_violations"] = \
            (horizon > sts.t + horizon_cap + eps).sum(dtype=jnp.int32)
    return out


def health_check_tenants(sts, t_prev, eps: float = 1e-9) -> dict:
    """Per-tenant verdicts of the same invariants as ``health_check`` over
    a vmapped tenant axis (``repro.serve``): every ``sts`` leaf carries a
    leading [T] lane axis ([T, N, ...]), and each tenant's verdict reduces
    only over its OWN neurons — one poisoned tenant never taints a
    neighbour's verdict.  Returns arrays [T]:

      nonfinite_lanes  i32[T]  neurons whose zn/t/h went non-finite
      clock_regress    i32[T]  neurons whose clock moved backwards
      solver_failed    bool[T] BDF gave up (latched ``failed``) on a
                               *finite* state — deterministic, so the
                               service evicts rather than retries
    """
    zn_ok = jnp.isfinite(sts.zn).all(axis=tuple(range(2, sts.zn.ndim)))
    lane_bad = jnp.logical_or(
        ~zn_ok, jnp.logical_or(~jnp.isfinite(sts.t), ~jnp.isfinite(sts.h)))
    return {
        "nonfinite_lanes": lane_bad.sum(axis=1, dtype=jnp.int32),
        "clock_regress": (sts.t < t_prev - eps).sum(axis=1, dtype=jnp.int32),
        "solver_failed": sts.failed.any(axis=1),
    }


def poison_lane(carry: SimCarry, lane: int, value=jnp.nan) -> SimCarry:
    """Fault injection: overwrite one lane's BDF history with ``value``
    (non-finite by default) — the failure mode the watchdog must catch."""
    sts = carry.sts
    return carry._replace(sts=sts._replace(zn=sts.zn.at[lane].set(value)))


def save_sim_checkpoint(ckpt_dir: str, rnd: int, carry: SimCarry,
                        extras: dict = None, keep: int = 3) -> str:
    """Atomic round-boundary snapshot (``repro.checkpoint`` commit
    protocol) + pruning.  ``rnd`` is the scheduler-round counter."""
    from repro.checkpoint import checkpoint as ck
    path = ck.save_checkpoint(ckpt_dir, rnd, carry, extras=extras)
    ck.prune_checkpoints(ckpt_dir, keep=keep)
    return path


def restore_sim_checkpoint(ckpt_dir: str, rnd: int, like: SimCarry,
                           shardings=None):
    """Restore a ``SimCarry`` snapshot into the structure of ``like``.

    Returns (carry, extras, skipped) where ``skipped`` lists the tree
    paths whose *stored* leaf shape no longer matches ``like`` — those
    leaves keep ``like``'s value.  Shape drift is only legitimate for the
    mesh-shape-dependent ``hcarry`` leaves (elastic resume onto a
    different mesh); callers must reject any other skip and reseed the
    horizon carry from the restored clocks.  Every restored leaf is
    integrity-checked (nbytes + crc32) and the stored treedef must match
    ``like``'s exactly.  ``shardings``: optional matching pytree of
    shardings — restored leaves are ``device_put`` with theirs (the
    ``restore_checkpoint(shardings=)`` elastic path).
    """
    from repro.checkpoint import checkpoint as ck

    path = ck.step_path(ckpt_dir, rnd)
    manifest = ck.read_manifest(path)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(f"checkpoint has {manifest['n_leaves']} leaves, "
                         f"expected {len(leaves)}")
    stored_td = manifest.get("treedef")
    if stored_td is not None and stored_td != str(treedef):
        raise ValueError(
            "checkpoint pytree structure does not match the restore target:"
            f"\n  stored:   {stored_td}\n  expected: {treedef}")
    paths = [jax.tree_util.keystr(kp) for kp, _ in
             jax.tree_util.tree_flatten_with_path(like)[0]]
    sh_leaves = treedef.flatten_up_to(shardings) if shardings is not None \
        else [None] * len(leaves)
    out, skipped = [], []
    for entry, leaf, pstr, sh in zip(manifest["entries"], leaves, paths,
                                     sh_leaves):
        arr = ck.load_leaf(path, entry)
        if tuple(arr.shape) != tuple(leaf.shape):
            skipped.append(pstr)
            out.append(leaf)
            continue
        a = arr.astype(leaf.dtype)
        out.append(jax.device_put(a, sh) if sh is not None
                   else jnp.asarray(a))
    return treedef.unflatten(out), manifest["extras"], skipped


def run_checkpointed(init_fn, step_fn, cond_fn, *, ckpt_dir=None,
                     checkpoint_every: int = 0, resume: bool = False,
                     keep: int = 3, fault=None, health_of=None,
                     max_rollbacks: int = 2, shardings=None, reseed=None,
                     fingerprint=None, extras_fn=None, log_fn=None,
                     straggler=None):
    """Host-stepped scheduler-round loop with round-boundary
    checkpoint/restore, fault injection and the health watchdog — the
    preemption-tolerance harness every vardt driver shares.

      init_fn() -> SimCarry                 fresh round-0 state
      step_fn(SimCarry) -> SimCarry         one jitted scheduler round
                                            (must bump counters["rounds"])
      cond_fn(SimCarry) -> bool             host-side continue predicate
      health_of(SimCarry, t_prev) -> dict   per-round watchdog scalars
                                            (``health_check``; None = off)
      fault: ``checkpoint.FaultPlan``       round-boundary injection
      reseed(SimCarry) -> SimCarry          re-derive skipped (elastic)
                                            ``hcarry`` leaves on restore
      fingerprint: JSON-able layout id      saved in the manifest extras;
                                            a restore whose stored value
                                            differs forces ``reseed`` even
                                            when leaf shapes coincide (a
                                            mesh-shape change can keep the
                                            hcarry widths while scrambling
                                            their shard-relative contents)

    Non-finite state detected by the watchdog quarantines the round:
    restore the last checkpoint (round 0 via ``init_fn`` when none exists
    yet) and retry, at most ``max_rollbacks`` times — then escalate
    ``rollback_exhausted`` (the caller folds it into ``RunResult.failed``).
    Detected, never silent: every event lands in the returned health dict.

    Returns (final SimCarry, health dict).
    """
    import time as _time

    from repro.checkpoint import checkpoint as ck
    from repro.checkpoint.fault_tolerance import (SimulatedFailure,
                                                  StragglerMonitor)

    if (resume or checkpoint_every) and not ckpt_dir:
        raise ValueError("checkpoint_every/resume need ckpt_dir=")
    log = log_fn or (lambda *_: None)
    # straggler: a caller-configured StragglerMonitor (window / regression
    # threshold knobs); default keeps the historical 32-round window
    monitor = straggler if straggler is not None else StragglerMonitor()
    health = empty_health(watchdog=health_of is not None)

    def _restore(rnd, like):
        carry, extras, skipped = restore_sim_checkpoint(
            ckpt_dir, rnd, like, shardings=shardings)
        stale = extras.get("fingerprint") != fingerprint \
            if fingerprint is not None else False
        if skipped or stale:
            bad = [p for p in skipped if "hcarry" not in p]
            if bad or reseed is None:
                raise ValueError(f"checkpoint leaf shapes changed outside "
                                 f"the horizon carry: {skipped}")
            carry = reseed(carry)
            health["elastic_reseeded"] = True
        return carry

    carry = init_fn()
    if resume:
        last = ck.latest_step(ckpt_dir)
        if last is not None:
            carry = _restore(last, carry)
            health["resumed_from"] = last
            log(f"[sim-ft] resumed from round {last}")
    rollbacks = 0
    poison_pending = fault is not None and fault.poison_at_round is not None
    fail_pending = fault is not None and fault.fail_at_round is not None
    while bool(cond_fn(carry)):
        rnd = int(carry.counters["rounds"])
        if fail_pending and rnd >= fault.fail_at_round:
            raise SimulatedFailure(rnd)
        if poison_pending and rnd >= fault.poison_at_round:
            poison_pending = False
            carry = poison_lane(carry, fault.poison_lane, fault.poison_value)
            log(f"[sim-ft] poisoned lane {fault.poison_lane} at round {rnd}")
        if fault is not None and fault.mutate is not None:
            carry = fault.mutate(rnd, carry)
        t_prev = carry.sts.t
        t0 = _time.time()
        new_carry = step_fn(carry)
        if health_of is not None:
            chk = {k: int(v) for k, v in health_of(new_carry, t_prev).items()}
            health["checks"] += 1
            health["clock_regressions"] += chk.get("clock_regress", 0)
            health["horizon_violations"] += chk.get("horizon_violations", 0)
            if chk.get("nonfinite_lanes", 0):
                health["nonfinite_rounds"] += 1
                rollbacks += 1
                if rollbacks > max_rollbacks:
                    health["rollback_exhausted"] = True
                    log(f"[sim-ft] non-finite state at round {rnd}: "
                        f"retries exhausted")
                    carry = new_carry
                    break
                health["rollbacks"] += 1
                last = ck.latest_step(ckpt_dir) if ckpt_dir else None
                log(f"[sim-ft] non-finite state at round {rnd}; rolling "
                    f"back to {'round ' + str(last) if last is not None else 'init'}")
                carry = init_fn() if last is None else _restore(last, carry)
                continue
        monitor.record(_time.time() - t0)
        carry = new_carry
        r2 = int(carry.counters["rounds"])
        if checkpoint_every and r2 % checkpoint_every == 0:
            ex = dict(extras_fn()) if extras_fn else {}
            if fingerprint is not None:
                ex["fingerprint"] = fingerprint
            save_sim_checkpoint(ckpt_dir, r2, carry, extras=ex or None,
                                keep=keep)
            health["checkpoints_saved"] += 1
    health["straggler"] = monitor.stats()
    return carry, health

"""Synaptic event queues: fixed-capacity, fully vectorised, SPMD-friendly.

The paper's HPX implementation delivers synaptic *parcels* point-to-point.
On SPMD hardware (DESIGN.md §3) we realise the same semantics with dense
per-neuron slot arrays: insertion is a batched scatter, delivery is a masked
reduction — both single XLA ops, no per-message control flow.

Empty slots hold ``t = +inf``.  Overflow (more pending events than capacity)
is *detected*, never silent: ``dropped`` accumulates and tests assert zero.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

INF = jnp.inf


class EventQueue(NamedTuple):
    t: jnp.ndarray        # f64[N, Q] delivery times (+inf = free)
    w_ampa: jnp.ndarray   # f64[N, Q]
    w_gaba: jnp.ndarray   # f64[N, Q]
    dropped: jnp.ndarray  # i32[] overflow counter


def make_queue(n: int, capacity: int, dtype=jnp.float64) -> EventQueue:
    return EventQueue(
        t=jnp.full((n, capacity), INF, dtype),
        w_ampa=jnp.zeros((n, capacity), dtype),
        w_gaba=jnp.zeros((n, capacity), dtype),
        dropped=jnp.zeros((), jnp.int32),
    )


def insert(eq: EventQueue, target, t_ev, w_ampa, w_gaba, valid) -> EventQueue:
    """Batched insert of E candidate events; invalid entries are ignored.

    target i32[E]; t_ev/w_ampa/w_gaba f64[E]; valid bool[E].
    Multiple events may share a target: each gets a distinct free slot via
    (segment-rank within target) -> (rank-th free slot of that neuron).
    """
    n, cap = eq.t.shape
    E = target.shape[0]
    tgt = jnp.where(valid, target, n)                       # park invalid at n
    order = jnp.argsort(tgt, stable=True)
    tgt_s = tgt[order]
    # rank of each event within its target segment
    idx = jnp.arange(E)
    seg_start = jnp.searchsorted(tgt_s, tgt_s, side="left")
    rank = idx - seg_start
    # rank-th free slot per neuron: free slots sorted first
    free = jnp.isinf(eq.t)                                   # [N, Q]
    slot_order = jnp.argsort(~free, axis=1, stable=True)     # free first
    n_free = free.sum(axis=1)
    tgt_c = jnp.clip(tgt_s, 0, n - 1)
    ok = jnp.logical_and(tgt_s < n, rank < n_free[tgt_c])
    slot = slot_order[tgt_c, jnp.clip(rank, 0, cap - 1)]
    row = jnp.where(ok, tgt_c, n)                            # drop-out-of-range
    te, wa, wg = t_ev[order], w_ampa[order], w_gaba[order]
    new_t = eq.t.at[row, slot].set(te, mode="drop")
    new_a = eq.w_ampa.at[row, slot].set(wa, mode="drop")
    new_g = eq.w_gaba.at[row, slot].set(wg, mode="drop")
    dropped = eq.dropped + jnp.sum(jnp.logical_and(tgt_s < n, ~ok)).astype(jnp.int32)
    return EventQueue(new_t, new_a, new_g, dropped)


def insert_rows(eq: EventQueue, target, t_ev, w_ampa, w_gaba,
                valid) -> EventQueue:
    """``insert`` for a *small* event batch: identical slot assignment and
    drop semantics, but the free-slot search touches only the targeted
    rows (one [E, Q] gather + cumsum) instead of argsorting the whole
    [N, Q] slot plane — O(E (Q + log E)) per call, independent of N.
    The compact fan-out path (``fanout="compact"``) inserts its gathered
    [spike_cap * k_out] edge batch through this.
    """
    n, cap = eq.t.shape
    E = target.shape[0]
    tgt = jnp.where(valid, target, n)                       # park invalid at n
    order = jnp.argsort(tgt, stable=True)
    tgt_s = tgt[order]
    idx = jnp.arange(E)
    rank = idx - jnp.searchsorted(tgt_s, tgt_s, side="left")
    tgt_c = jnp.clip(tgt_s, 0, n - 1)
    free_rows = jnp.isinf(eq.t[tgt_c])                      # [E, Q]
    csum = jnp.cumsum(free_rows.astype(jnp.int32), axis=1)
    ok = jnp.logical_and(tgt_s < n, rank < csum[:, -1])
    # the rank-th free slot in ascending slot order == insert's slot_order
    hit = jnp.logical_and(free_rows, csum == (rank + 1)[:, None])
    slot = jnp.argmax(hit, axis=1).astype(jnp.int32)
    row = jnp.where(ok, tgt_c, n)
    te, wa, wg = t_ev[order], w_ampa[order], w_gaba[order]
    new_t = eq.t.at[row, slot].set(te, mode="drop")
    new_a = eq.w_ampa.at[row, slot].set(wa, mode="drop")
    new_g = eq.w_gaba.at[row, slot].set(wg, mode="drop")
    dropped = eq.dropped + jnp.sum(jnp.logical_and(tgt_s < n, ~ok)).astype(jnp.int32)
    return EventQueue(new_t, new_a, new_g, dropped)


def next_time(eq: EventQueue):
    """Earliest pending delivery time per neuron, +inf if none.  f64[N]."""
    return eq.t.min(axis=1)


def deliver_until(eq: EventQueue, t_dl):
    """Pop all events with t <= t_dl (per neuron); return summed weights.

    t_dl: f64[N].  Returns (eq', w_ampa[N], w_gaba[N], n_delivered[N]).
    """
    due = eq.t <= t_dl[:, None]
    wa = jnp.sum(jnp.where(due, eq.w_ampa, 0.0), axis=1)
    wg = jnp.sum(jnp.where(due, eq.w_gaba, 0.0), axis=1)
    cnt = due.sum(axis=1).astype(jnp.int32)
    new_t = jnp.where(due, INF, eq.t)
    return EventQueue(new_t, eq.w_ampa, eq.w_gaba, eq.dropped), wa, wg, cnt


class SpikeRecord(NamedTuple):
    """Global spike-train recorder with fixed capacity per neuron."""
    times: jnp.ndarray    # f64[N, S]
    count: jnp.ndarray    # i32[N]
    overflow: jnp.ndarray  # i32[]


def make_spike_record(n: int, capacity: int = 128, dtype=jnp.float64) -> SpikeRecord:
    return SpikeRecord(jnp.full((n, capacity), INF, dtype),
                       jnp.zeros((n,), jnp.int32), jnp.zeros((), jnp.int32))


def record_spikes(rec: SpikeRecord, neuron, t_spike, valid) -> SpikeRecord:
    """Append spikes (neuron[i], t_spike[i]) for valid[i]."""
    n, cap = rec.times.shape
    # at most one spike per neuron per call in our drivers -> slot = count
    row = jnp.where(valid, neuron, n)
    slot = rec.count[jnp.clip(neuron, 0, n - 1)]
    ok = slot < cap
    row = jnp.where(ok, row, n)
    times = rec.times.at[row, jnp.clip(slot, 0, cap - 1)].set(t_spike, mode="drop")
    count = rec.count.at[jnp.where(valid & ok, neuron, n)].add(1, mode="drop")
    overflow = rec.overflow + jnp.sum(valid & ~ok).astype(jnp.int32)
    return SpikeRecord(times, count, overflow)

"""Calibration utilities: threshold current (paper Fig. 6's x-axis) and
regime-current search (paper §4's five spiking regimes)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cell import CellModel
from repro.core.exec_common import SPIKE_THR
from repro.core.fixed_step import run_fixed


def spikes_in_trace(vs, thr=SPIKE_THR) -> int:
    vs = np.asarray(vs)
    return int(((vs[1:] > thr) & (vs[:-1] <= thr)).sum())


def _n_spikes(model: CellModel, iinj: float, t_end: float, dt=0.025) -> int:
    y0 = model.init_state()
    _, _, tr = run_fixed(model, y0, t_end, iinj, method="cnexp", dt=dt,
                         record_every=4)
    return spikes_in_trace(tr)


def threshold_current(model: CellModel, t_end: float = 200.0,
                      lo: float = 0.0, hi: float = 1.0,
                      iters: int = 12) -> float:
    """Minimum continuous current that elicits a spike (bisection)."""
    while _n_spikes(model, hi, t_end) == 0:
        hi *= 2.0
        if hi > 1e3:
            raise RuntimeError("no spiking found up to 1000 nA")
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if _n_spikes(model, mid, t_end) > 0:
            hi = mid
        else:
            lo = mid
    return hi


def current_for_rate(model: CellModel, rate_hz: float, i_thresh: float,
                     t_end: float = 1000.0, iters: int = 10) -> float:
    """Continuous current giving approximately ``rate_hz`` (bisection on the
    f-I curve, which is monotone for HH under DC input)."""
    target = rate_hz * t_end / 1000.0
    lo, hi = 0.8 * i_thresh, 6.0 * i_thresh
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        n = _n_spikes(model, mid, t_end)
        if n < target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)

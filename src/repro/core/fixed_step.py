"""Fixed-timestep solvers: the paper's baseline methods.

  cnexp          (1a) staggered: gates advanced *analytically* (exact
                 exponential for the linear gating ODE at frozen V), voltage
                 by an implicit linear Hines solve — NEURON's default.
  euler          (1b) staggered: gates by *explicit* Euler (no exp/div),
                 voltage implicit linear Hines solve.
  derivimplicit  (2a) staggered: gates (and the complex correlated mechanism)
                 advanced by per-mechanism implicit Newton, voltage implicit
                 linear Hines solve — the reference fixed-step solver for
                 complex models.

All three share the staggered voltage update (paper §2.2): channel states are
evaluated at t + dt/2, making the voltage system *linear* and solvable with
one O(C) Hines sweep per step.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import mechanisms as mech
from repro.core.cell import CellModel
from repro.core.hines import hines_assemble, hines_solve

DT_DEFAULT = 0.025  # ms, paper's production step


def _voltage_update(model: CellModel, v, m, h, n, g_ampa, g_gaba, iinj, dt):
    """Implicit (backward-Euler) voltage step with frozen channel states."""
    p = model.params
    g_na, g_k, g_l = mech.channel_conductances(p.area, m, h, n)
    g_tot = g_na + g_k + g_l
    rhs = (p.cap / dt) * v + g_na * mech.ENA + g_k * mech.EK + g_l * mech.EL
    g_tot = g_tot.at[0].add(g_ampa + g_gaba)
    rhs = rhs.at[0].add(g_ampa * mech.E_AMPA + g_gaba * mech.E_GABA + iinj)
    d = hines_assemble(p.parent, p.g_axial, p.cap / dt + g_tot)
    return hines_solve(p.parent, p.g_axial, d, rhs)


def _gates_cnexp(v, m, h, n, dt):
    (mi, tm), (hi, th), (ni, tn) = mech.gate_inf_tau(v)
    em, eh, en = jnp.exp(-dt / tm), jnp.exp(-dt / th), jnp.exp(-dt / tn)
    return mi + (m - mi) * em, hi + (h - hi) * eh, ni + (n - ni) * en


def _gates_euler(v, m, h, n, dt):
    dm, dh, dn = mech.gate_derivs(v, m, h, n)
    return m + dt * dm, h + dt * dh, n + dt * dn


def _gates_derivimplicit(v, m, h, n, dt):
    # backward Euler on dx/dt = a(1-x) - b x (linear in x at frozen V):
    #   x' = (x + dt*a) / (1 + dt*(a+b))
    r = mech.gate_rates(v)
    m2 = (m + dt * r.a_m) / (1.0 + dt * (r.a_m + r.b_m))
    h2 = (h + dt * r.a_h) / (1.0 + dt * (r.a_h + r.b_h))
    n2 = (n + dt * r.a_n) / (1.0 + dt * (r.a_n + r.b_n))
    return m2, h2, n2


def _plasticity_derivimplicit(extra, dt, newton_iters: int = 3):
    """Per-mechanism implicit Newton on the correlated (ca, rho) pair."""

    def g(e_new):
        dca, drho = mech.plasticity_derivs(e_new[0], e_new[1])
        return e_new - extra - dt * jnp.stack([dca, drho])

    e = extra
    jac = jax.jacfwd(g)
    for _ in range(newton_iters):
        e = e - jnp.linalg.solve(jac(e), g(e))
    return e


def _plasticity_explicit(extra, dt):
    dca, drho = mech.plasticity_derivs(extra[0], extra[1])
    return extra + dt * jnp.stack([dca, drho])


GATE_UPDATES = {
    "cnexp": _gates_cnexp,
    "euler": _gates_euler,
    "derivimplicit": _gates_derivimplicit,
}


def make_stepper(model: CellModel, method: str = "cnexp", dt: float = DT_DEFAULT):
    """Returns step(y, iinj) -> y' advancing one fixed step of size dt."""
    if method not in GATE_UPDATES:
        raise ValueError(f"unknown fixed-step method {method!r}")
    gate_fn = GATE_UPDATES[method]

    def step(y, iinj=0.0):
        v, m, h, n, g_ampa, g_gaba, extra = model.split(y)
        # staggered: states to t+dt/2 using V(t) ...
        m, h, n = gate_fn(v, m, h, n, dt)
        g_ampa = g_ampa * jnp.exp(-dt / mech.TAU_AMPA)
        g_gaba = g_gaba * jnp.exp(-dt / mech.TAU_GABA)
        if model.with_plasticity:
            if method == "derivimplicit":
                extra = _plasticity_derivimplicit(extra, dt)
            else:
                extra = _plasticity_explicit(extra, dt)
        # ... then V(t) -> V(t+dt) with frozen states
        v = _voltage_update(model, v, m, h, n, g_ampa, g_gaba, iinj, dt)
        return model.pack(v, m, h, n, g_ampa, g_gaba, extra)

    return step


def run_fixed(model: CellModel, y0, t_end: float, iinj=0.0,
              method: str = "cnexp", dt: float = DT_DEFAULT,
              record_every: int = 0):
    """Integrate [0, t_end] with a fixed step; optionally record V_soma trace.

    Returns (y_final, n_steps, trace | None).
    """
    n_steps = int(round(t_end / dt))
    step = make_stepper(model, method, dt)

    if record_every:
        n_rec = n_steps // record_every

        def body(y, _):
            def inner(y, _):
                return step(y, iinj), None
            y, _ = jax.lax.scan(inner, y, None, length=record_every)
            return y, y[model.idx_vsoma]

        y, vs = jax.lax.scan(body, y0, None, length=n_rec)
        return y, n_steps, vs

    def body(y, _):
        return step(y, iinj), None

    y, _ = jax.lax.scan(body, y0, None, length=n_steps)
    return y, n_steps, None

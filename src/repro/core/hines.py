"""Hines solve of the quasi-tridiagonal compartmental-tree system (pure jnp).

The membrane system for one neuron is ``(D - A) v = b`` where D is diagonal
and A couples each compartment i to parent[i] with symmetric off-diagonal
value ``-g_axial[i]``.  With Hines ordering (parent[i] < i) it is solved
exactly in O(C): one child->parent elimination sweep and one parent->child
substitution sweep.

This module is the **reference implementation** (also ``kernels/hines/ref.py``
semantics); the TPU Pallas kernel lives in ``repro.kernels.hines``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hines_assemble(parent, g_axial, diag_extra):
    """Diagonal of the tree matrix: diag_extra[i] + g_ax[i] + sum_children g_ax[c].

    ``diag_extra`` carries the capacitive + ionic terms (c/dt + g_ion etc.).
    """
    d = diag_extra + g_axial
    # add each child's axial conductance onto its parent's diagonal.
    # parent[0] == -1 writes g_axial[0] == 0 onto the last row: a no-op by
    # construction (the root has no to-parent conductance).
    contrib = jnp.zeros_like(d).at[parent].add(g_axial)
    return d + contrib


def hines_solve(parent, g_axial, d, b):
    """Solve the Hines-ordered tree system; returns v with (D-A)v = b.

    parent: int32[C] (parent[0] == -1), g_axial: f64[C] (to-parent conductance),
    d: f64[C] assembled diagonal, b: f64[C] right-hand side.
    """
    C = d.shape[0]

    def elim(i, carry):
        dd, bb = carry
        idx = C - 1 - i                       # C-1 .. 1
        p = parent[idx]
        f = g_axial[idx] / dd[idx]
        dd = dd.at[p].add(-f * g_axial[idx])
        bb = bb.at[p].add(f * bb[idx])
        return dd, bb

    d, b = jax.lax.fori_loop(0, C - 1, elim, (d, b))

    v0 = b[0] / d[0]
    v = jnp.zeros_like(b).at[0].set(v0)

    def subst(i, v):
        p = parent[i]
        vi = (b[i] + g_axial[i] * v[p]) / d[i]
        return v.at[i].set(vi)

    v = jax.lax.fori_loop(1, C, subst, v)
    return v


def hines_factor(parent, g_axial, d):
    """Forward-eliminate the assembled diagonal; returns ``d_elim``.

    The elimination sweep of :func:`hines_solve` updates the diagonal
    independently of the right-hand side (children carry higher indices,
    so ``dd[idx]`` is final when row ``idx`` is eliminated).  That makes
    the eliminated diagonal an LU-style factorization of the tree matrix:
    together with ``g_axial`` it determines the multipliers
    ``f[i] = g_axial[i] / d_elim[i]``, so repeated solves against the
    same ``(d, g_axial)`` reuse this factor via
    :func:`hines_solve_factored` at two O(C) sweeps with no elimination.
    """
    C = d.shape[0]

    def elim(i, dd):
        idx = C - 1 - i                       # C-1 .. 1
        p = parent[idx]
        f = g_axial[idx] / dd[idx]
        return dd.at[p].add(-f * g_axial[idx])

    return jax.lax.fori_loop(0, C - 1, elim, d)


def hines_solve_factored(parent, g_axial, d_elim, b):
    """Solve ``(D - A) v = b`` from a stored :func:`hines_factor` result.

    Two O(C) sweeps: forward-substitute b with the cached multipliers,
    then back-substitute against the eliminated diagonal.  The value
    sequence matches :func:`hines_solve` operation for operation, so a
    factored solve equals the fused solve bit for bit.
    """
    C = d_elim.shape[0]

    def fwd(i, bb):
        idx = C - 1 - i                       # C-1 .. 1
        p = parent[idx]
        f = g_axial[idx] / d_elim[idx]
        return bb.at[p].add(f * bb[idx])

    b = jax.lax.fori_loop(0, C - 1, fwd, b)

    v0 = b[0] / d_elim[0]
    v = jnp.zeros_like(b).at[0].set(v0)

    def subst(i, v):
        p = parent[i]
        vi = (b[i] + g_axial[i] * v[p]) / d_elim[i]
        return v.at[i].set(vi)

    return jax.lax.fori_loop(1, C, subst, v)


def dense_tree_matrix(parent, g_axial, diag_extra):
    """Materialise the full dense matrix (test oracle only)."""
    C = diag_extra.shape[0]
    d = hines_assemble(parent, g_axial, diag_extra)
    mat = jnp.diag(d)
    rows = jnp.arange(1, C)
    cols = parent[1:]
    mat = mat.at[rows, cols].add(-g_axial[1:])
    mat = mat.at[cols, rows].add(-g_axial[1:])
    return mat

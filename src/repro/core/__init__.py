"""Core of the paper's contribution: fully-asynchronous fully-implicit
variable-order variable-timestep simulation of networks of detailed neurons.

Numerical accuracy of the BDF integrator requires float64; we enable x64 at
import time of this subpackage (LM-side code under ``repro.models`` uses
explicit float32/bfloat16 dtypes everywhere and is unaffected).
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.morphology import Morphology, ball_and_stick, branched_tree  # noqa: E402,F401
from repro.core.cell import CellModel, CellParams  # noqa: E402,F401
from repro.core.hines import hines_solve, hines_assemble  # noqa: E402,F401

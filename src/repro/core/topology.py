"""Structured connectivity generators: the ``topology=`` knob of make_network.

Real cortical models are not uniform-random: long-range interconnections
ride on locally-clustered wiring (Pastorelli et al., arXiv:1902.08410), and
simulators have always exploited that structure (Brette et al. review).
For the asynchronous execution model the structure is *the* lever on
communication cost — with uniform wiring every neuron sits on a shard
boundary and the notify frontier (``sharding.shard_frontier``) degenerates
to all-gather-shaped traffic, while clustered wiring lets locality-aware
placement (``distributed.placement``) shrink it by the locality factor.

Every generator preserves ``make_network``'s static edge layout — edges
grouped by postsynaptic neuron with uniform in-degree k_in (``post ==
repeat(arange(n), k_in)``) — so the grouped queue-insert fast paths,
``WheelSpec.auto`` and the SPMD round's shard-local insert all run
unmodified on structured nets.

Generators (``TopologyConfig.name``):

``uniform``
    The seed behaviour: presynaptic ids i.i.d. uniform over [0, n).  Draws
    the same rng stream as the pre-knob ``make_network``, so seeded
    networks are bit-identical to before the knob existed.
``block``
    Clustered/block-modular wiring: neurons partition into ``n_blocks``
    contiguous blocks; each in-edge draws its pre from the post's own
    block with probability ``p_in``, else uniformly from the other blocks.
``ring``
    1-D distance-dependent falloff: pre at signed circular offset whose
    magnitude is geometric with mean ``sigma`` neurons.
``grid2d``
    2-D torus (row-major ids on a side x side grid, n = side**2): pre at a
    wrapped 2-D offset with isotropic discrete-Gaussian components of
    scale ``sigma``.
``smallworld``
    Watts-Strogatz: ring lattice of the k_in nearest neighbours, each
    edge rewired to a uniform pre with probability ``p_rewire``.

Each structured net carries per-neuron *block metadata* (``Network.block``:
i32[N], the locality unit — the cluster for ``block``, a contiguous tile of
``n // n_blocks`` neurons otherwise) from which per-edge locality is
*measured*, never assumed: ``edge_block_pairs`` / ``intra_block_frac`` here,
cut-edge and frontier statistics in ``distributed.placement``.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import numpy as np

TOPOLOGIES = ("uniform", "block", "ring", "grid2d", "smallworld")


class TopologyConfig(NamedTuple):
    """Static connectivity-structure parameters (host-side constants).

    n_blocks: locality units for ``block`` (and the metadata tiling of the
        spatial topologies); must divide n.
    p_in:     ``block`` — probability an in-edge stays within its block.
    sigma:    ``ring``/``grid2d`` — distance-falloff scale in neurons
              (mean |offset| on the ring, per-axis std on the grid).
    p_rewire: ``smallworld`` — Watts-Strogatz rewiring probability.
    """
    name: str = "uniform"
    n_blocks: int = 8
    p_in: float = 0.9
    sigma: float = 4.0
    p_rewire: float = 0.05


def as_config(topology) -> TopologyConfig:
    """Coerce the ``topology=`` knob (name or config) to a TopologyConfig."""
    if isinstance(topology, TopologyConfig):
        cfg = topology
    elif isinstance(topology, str):
        cfg = TopologyConfig(name=topology)
    else:
        raise TypeError(f"topology must be a name or TopologyConfig, "
                        f"got {topology!r}")
    if cfg.name not in TOPOLOGIES:
        raise ValueError(f"unknown topology {cfg.name!r} "
                         f"(want one of {TOPOLOGIES})")
    return cfg


def _tile_blocks(n: int, n_blocks: int) -> np.ndarray:
    if n_blocks <= 0 or n % n_blocks:
        raise ValueError(f"n_blocks={n_blocks} must divide n={n}")
    return (np.arange(n, dtype=np.int32) // (n // n_blocks)).astype(np.int32)


def _pre_uniform(rng, n, k_in, cfg):
    # exactly the seed network's single draw — keeps seeded nets identical
    return rng.integers(0, n, size=n * k_in).astype(np.int32), None


def _pre_block(rng, n, k_in, cfg):
    block = _tile_blocks(n, cfg.n_blocks)
    bs = n // cfg.n_blocks
    E = n * k_in
    post_block = np.repeat(block, k_in).astype(np.int64)
    stay = rng.random(E) < cfg.p_in
    off_in = rng.integers(0, bs, size=E)
    pre_in = post_block * bs + off_in
    if cfg.n_blocks == 1:
        return pre_in.astype(np.int32), block     # one block: nowhere else
    off_out = rng.integers(0, n - bs, size=E)        # uniform over other blocks
    out = off_out + np.where(off_out >= post_block * bs, bs, 0)
    pre = np.where(stay, pre_in, out)
    return pre.astype(np.int32), block


def _pre_ring(rng, n, k_in, cfg):
    E = n * k_in
    post = np.repeat(np.arange(n, dtype=np.int64), k_in)
    p = 1.0 / max(float(cfg.sigma), 1.0)
    mag = rng.geometric(p, size=E)                    # >= 1: never self
    sign = rng.integers(0, 2, size=E) * 2 - 1
    pre = (post + sign * mag) % n
    return pre.astype(np.int32), _tile_blocks(n, cfg.n_blocks)


def _pre_grid2d(rng, n, k_in, cfg):
    side = math.isqrt(n)
    if side * side != n:
        raise ValueError(f"grid2d needs a square neuron count, got n={n}")
    E = n * k_in
    post = np.repeat(np.arange(n, dtype=np.int64), k_in)
    px, py = post % side, post // side
    dx = np.rint(rng.normal(0.0, cfg.sigma, size=E)).astype(np.int64)
    dy = np.rint(rng.normal(0.0, cfg.sigma, size=E)).astype(np.int64)
    dx = np.where((dx == 0) & (dy == 0), 1, dx)       # no zero offset
    pre = ((py + dy) % side) * side + (px + dx) % side
    return pre.astype(np.int32), _tile_blocks(n, cfg.n_blocks)


def _pre_smallworld(rng, n, k_in, cfg):
    if k_in >= n:
        raise ValueError(f"smallworld lattice needs k_in < n ({k_in} vs {n})")
    half = k_in // 2
    offs = np.concatenate([np.arange(1, half + 1),
                           -np.arange(1, k_in - half + 1)])[:k_in]
    post = np.repeat(np.arange(n, dtype=np.int64), k_in)
    lattice = (post + np.tile(offs, n)) % n
    E = n * k_in
    rew = rng.random(E) < cfg.p_rewire
    rand = rng.integers(0, n, size=E)
    pre = np.where(rew, rand, lattice)
    return pre.astype(np.int32), _tile_blocks(n, cfg.n_blocks)


_GENERATORS = {"uniform": _pre_uniform, "block": _pre_block,
               "ring": _pre_ring, "grid2d": _pre_grid2d,
               "smallworld": _pre_smallworld}


def sample_pre(cfg: TopologyConfig, rng: np.random.Generator, n: int,
               k_in: int):
    """Draw the presynaptic ids of the grouped by-post edge layout.

    Returns (pre i32[n*k_in], block i32[n] | None): edge j*k_in+e is the
    e-th in-edge of postsynaptic neuron j; ``block`` is the per-neuron
    locality metadata (None for the structureless ``uniform``).
    """
    return _GENERATORS[cfg.name](rng, n, k_in, cfg)


# ---------------------------------------------------------------------------
# measured per-edge locality (never assumed from the generator parameters)
# ---------------------------------------------------------------------------
def edge_block_pairs(net) -> Optional[np.ndarray]:
    """Per-edge block metadata: i32[E, 2] of (block[pre], block[post]),
    or None when the net carries no block structure."""
    if net.block is None:
        return None
    b = np.asarray(net.block)
    return np.stack([b[np.asarray(net.pre)], b[np.asarray(net.post)]], axis=1)


def intra_block_frac(net) -> float:
    """Measured fraction of edges that stay inside their block."""
    pairs = edge_block_pairs(net)
    if pairs is None:
        raise ValueError("net has no block metadata (uniform topology)")
    return float((pairs[:, 0] == pairs[:, 1]).mean())


def ring_distance(net) -> np.ndarray:
    """Per-edge circular |pre - post| distance (neurons)."""
    n = int(net.n)
    d = (np.asarray(net.pre, np.int64) - np.asarray(net.post, np.int64)) % n
    return np.minimum(d, n - d)

"""Synthetic neural networks: connectivity, synaptic delays, spiking regimes.

Connectivity mirrors the statistics the paper reports for the Markram et al.
digital reconstruction: per-neuron in-degree with AMPA/GABA receptor mix,
synaptic delays >= 0.1 ms with a long-tailed (lognormal) distribution whose
mode sits well above the BSP communication interval (paper Fig. 3 — only
~0.13% of synapses sit at the 0.1 ms minimum).

Wiring *structure* is the ``topology=`` knob (``repro.core.topology``):
uniform-random (the seed behaviour, bit-identical for a given seed) or the
structured generators (block/clustered, ring and 2-D-grid distance falloff,
small-world) whose per-neuron block metadata (``Network.block``) makes
locality measurable — the input to locality-aware shard placement
(``repro.distributed.placement``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from repro.core import topology as topo

MIN_DELAY = 0.1      # ms — the BSP communication interval (paper §1)
MAX_DELAY = 7.0      # ms — Fig. 3 cut-off (>7 ms is <1% of synapses)


class Network(NamedTuple):
    n: int
    pre: np.ndarray        # i32[E]
    post: np.ndarray       # i32[E]
    delay: np.ndarray      # f64[E] ms
    w_ampa: np.ndarray     # f64[E] uS (0 for GABA synapses)
    w_gaba: np.ndarray     # f64[E] uS (0 for AMPA synapses)
    min_delay: float
    block: Optional[np.ndarray] = None   # i32[N] locality unit (topology)

    @property
    def n_edges(self) -> int:
        return int(self.pre.shape[0])


def sample_delays(rng: np.random.Generator, size: int) -> np.ndarray:
    """Lognormal delays clipped to [MIN_DELAY, MAX_DELAY] (Fig. 3 shape)."""
    d = rng.lognormal(mean=0.0, sigma=0.75, size=size)      # mode ~ 0.57 ms
    return np.clip(d, MIN_DELAY, MAX_DELAY)


def make_network(n: int, k_in: int = 16, pct_gaba: float = 0.2,
                 w_exc: float = 1.0e-4, w_inh: float = 3.0e-4,
                 seed: int = 0, allow_self: bool = False,
                 topology="uniform") -> Network:
    """Network with k_in synapses per neuron, wired per the topology knob.

    topology: a ``repro.core.topology`` generator name ("uniform", "block",
    "ring", "grid2d", "smallworld") or a ``TopologyConfig``; "uniform"
    reproduces the historical networks bit-for-bit at a given seed.
    Weights are conductance increments per event (uS); defaults produce
    physiological EPSP sizes on the 20 um soma used in benchmarks.
    """
    cfg = topo.as_config(topology)
    rng = np.random.default_rng(seed)
    post = np.repeat(np.arange(n, dtype=np.int32), k_in)
    pre, block = topo.sample_pre(cfg, rng, n, k_in)
    if not allow_self:
        clash = pre == post
        pre[clash] = (pre[clash] + 1) % n
    delay = sample_delays(rng, n * k_in)
    is_gaba = rng.random(n * k_in) < pct_gaba
    w = rng.exponential(1.0, size=n * k_in)
    w_ampa = np.where(is_gaba, 0.0, w * w_exc)
    w_gaba = np.where(is_gaba, w * w_inh, 0.0)
    return Network(n=n, pre=pre, post=post, delay=delay,
                   w_ampa=w_ampa, w_gaba=w_gaba, min_delay=float(delay.min()),
                   block=block)


def regime_current(regime: str, i_thresh: float) -> float:
    """Continuous current driving a neuron to a given spiking regime.

    The five regimes of paper §4 (quiet 0.25 Hz .. burst 55.8 Hz) are set up
    in benchmarks by calibrating current against the threshold current, as
    the paper does (Fig. 6's x-axis is % of threshold current).
    """
    factors = {"quiet": 0.95, "slow": 1.02, "moderate": 1.12,
               "fast": 1.8, "burst": 3.2}
    if regime not in factors:
        raise ValueError(f"unknown regime {regime!r}")
    return factors[regime] * i_thresh


def delay_histogram(net: Network, bin_ms: float = 0.1):
    """Paper Fig. 3: histogram of synaptic delays, 0.1 ms bins."""
    bins = np.arange(MIN_DELAY, MAX_DELAY + bin_ms, bin_ms)
    hist, edges = np.histogram(net.delay, bins=bins)
    return hist, edges

"""Carefully-speculative FAP variable-timestep execution.

The paper's §2.4 found NAIVE speculation (step anywhere, backstep on late
events) infeasible at scale: reverting *sent* spikes cascades across
compute nodes.  Its §Discussion proposes, as future work, "a carefully-
speculative execution model performing speculative stepping while avoiding
the initiation of cascades".  This module implements that proposal:

  per round:
    1. advance conservatively to the dependency horizon (identical to
       exec_fap: non-speculative, spikes fan out immediately),
    2. SNAPSHOT the validated state, then keep stepping speculatively up to
       ``spec_window`` ms past the horizon — but HOLD any spike emitted in
       the speculated span (no event leaves the neuron => nothing to revert
       on other ranks, ever),
    3. next round, if an event arrives inside a neuron's speculated span,
       restore its snapshot (a local, communication-free backstep) and drop
       its held spike; otherwise the speculation is validated: the clock
       keeps its head start and the held spike (if its time is now at or
       below the validated horizon) is emitted normally.

Quiet networks validate nearly all speculation, so effective step lengths
grow past the min-in-delay horizon bound (the §4.3 limit of the
non-speculative method); active networks pay only discarded local work.
Spike trains are bit-identical to the non-speculative method whenever
speculation is validated, and equal up to integrator tolerance otherwise
(tests/test_speculative.py).

Speculation composes with the Newton factor cache for free: the cached
factors, freshness counters and ``gamma_saved`` live in ``BDFState``, so
the snapshot/restore pytree carries them like any other solver state.
Speculative steps ride whatever factors the conservative phase left
behind (stale factors stay valid — the freshness policy rebuilds on
gamma drift or convergence decay exactly as in validated stepping), and
a backstep restores the snapshot's factors without any extra setup:
discarded speculation never forces a Jacobian rebuild.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import sched
from repro.core import bdf
from repro.core import events as ev
from repro.core import exec_common as xc
from repro.core.cell import CellModel
from repro.core.exec_bsp import EV_CAP, SPK_CAP, RunResult, make_vardt_advance
from repro.core.network import Network


class SpecStats(NamedTuple):
    hits: jnp.ndarray          # validated speculations
    backsteps: jnp.ndarray     # discarded speculations (local, no cascade)
    wasted_steps: jnp.ndarray  # BDF steps thrown away
    held_spikes: jnp.ndarray   # spikes held then emitted after validation


def make_spec_runner(model: CellModel, net: Network, iinj, t_end: float,
                     opts: bdf.BDFOptions = bdf.BDFOptions(),
                     horizon_cap: float = 2.0, spec_window: float = 2.0,
                     step_budget: int = 12, ev_cap: int = EV_CAP,
                     max_rounds: int = 1_000_000, queue: str = "dense",
                     wheel: sched.WheelSpec = sched.WheelSpec(),
                     fanout: str = "dense", spike_cap: int = 0):
    n = net.n
    dnet = xc.to_device(net)
    qops = sched.get_queue_ops(queue, ev_cap=ev_cap, wheel=wheel)
    qinsert = sched.edge_insert(qops, net)
    spike_ins = xc.make_spike_insert(net, dnet, qops, qinsert, fanout,
                                     spike_cap)
    iinj_v = jnp.broadcast_to(jnp.asarray(iinj, jnp.float64), (n,))
    neuron_ids = jnp.arange(n, dtype=jnp.int32)     # hoisted round constant
    advance = make_vardt_advance(model, opts, 0.0, step_budget)
    vadvance = jax.vmap(advance)

    def round_body(carry):
        (sts, snap, spec_on, held_sp, held_t, eq, rec, n_ev, n_rs, stats,
         rounds) = carry
        # ---- validation of last round's speculation ----------------------
        # an event due before the speculated clock invalidates the neuron
        next_ev = qops.next_time(eq)
        invalid = jnp.logical_and(spec_on, next_ev < sts.t - 1e-12)
        valid = jnp.logical_and(spec_on, ~invalid)
        wasted = jnp.where(invalid, sts.nst - snap.nst, 0).sum(dtype=jnp.int32)
        sts = jax.tree_util.tree_map(
            lambda s, z: jnp.where(
                invalid.reshape((-1,) + (1,) * (s.ndim - 1)), z, s),
            sts, snap)
        held_sp = jnp.logical_and(held_sp, ~invalid)   # drop unvalidated spikes
        stats = SpecStats(
            hits=stats.hits + valid.sum(dtype=jnp.int32),
            backsteps=stats.backsteps + invalid.sum(dtype=jnp.int32),
            wasted_steps=stats.wasted_steps + wasted,
            held_spikes=stats.held_spikes)

        # ---- conservative phase (identical to exec_fap) -------------------
        t_clock = sts.t
        horizon = xc.horizon_times(dnet, n, t_clock, t_end)
        horizon = jnp.minimum(horizon, t_clock + horizon_cap)
        runnable = t_clock < horizon - 1e-12
        sts, eq_t, spiked, t_sp, nd, nrs = vadvance(
            sts, eq.t, eq.w_ampa, eq.w_gaba, horizon, runnable, iinj_v)
        eq = eq._replace(t=eq_t)
        # emit held spikes validated by this round's horizon
        emit_held = jnp.logical_and(held_sp, held_t <= horizon + 1e-12)
        stats = stats._replace(
            held_spikes=stats.held_spikes + emit_held.sum(dtype=jnp.int32))
        held_sp = jnp.logical_and(held_sp, ~emit_held)
        all_spiked = jnp.logical_or(spiked, emit_held)
        all_tsp = jnp.where(emit_held, held_t, t_sp)
        rec = ev.record_spikes(rec, neuron_ids, all_tsp, all_spiked)
        eq = spike_ins(eq, all_spiked, all_tsp)

        # ---- speculative phase (hold spikes; nothing leaves the neuron) ---
        snap = sts
        spec_limit = jnp.minimum(horizon + spec_window, t_end)
        next_ev2 = qops.next_time(eq)
        can_spec = jnp.logical_and(sts.t < spec_limit - 1e-12,
                                   next_ev2 > spec_limit)  # no known event due
        sts2, _, sp2, tsp2, _, _ = vadvance(
            sts, eq.t, eq.w_ampa, eq.w_gaba, spec_limit, can_spec, iinj_v)
        # neurons holding an un-emitted spike may not speculate further
        sp_ok = jnp.logical_and(can_spec, ~held_sp)
        sts = jax.tree_util.tree_map(
            lambda a, b: jnp.where(
                sp_ok.reshape((-1,) + (1,) * (a.ndim - 1)), a, b),
            sts2, sts)
        new_held = jnp.logical_and(sp_ok, sp2)
        held_sp = jnp.logical_or(held_sp, new_held)
        held_t = jnp.where(new_held, tsp2, held_t)
        spec_on = sp_ok

        return (sts, snap, spec_on, held_sp, held_t, eq, rec,
                n_ev + nd.sum(dtype=jnp.int32),
                n_rs + nrs.sum(dtype=jnp.int32), stats, rounds + 1)

    def cond(carry):
        sts, snap = carry[0], carry[1]
        rounds = carry[-1]
        # progress is measured on the VALIDATED clock (snapshot)
        return jnp.logical_and(snap.t.min() < t_end - 1e-9,
                               jnp.logical_and(rounds < max_rounds,
                                               ~sts.failed.any()))

    @jax.jit
    def run():
        Y = xc.batch_init(model, n)
        sts = jax.vmap(lambda y, i: bdf.reinit(model, 0.0, y, i, opts))(Y, iinj_v)
        eq = qops.make(n)
        rec = ev.make_spike_record(n, SPK_CAP)
        z = jnp.zeros((), jnp.int32)
        stats = SpecStats(z, z, z, z)
        carry = (sts, sts, jnp.zeros((n,), bool), jnp.zeros((n,), bool),
                 jnp.zeros((n,)), eq, rec, z, z, stats, z)
        (sts, snap, _, _, _, eq, rec, n_ev, n_rs, stats, rounds) = \
            jax.lax.while_loop(cond, round_body, carry)
        res = RunResult(rec, snap.nst.sum(), n_ev, n_rs, eq.dropped,
                        sts.failed.any(), snap.zn[:, 0],
                        solver=xc.solver_stats(snap))
        return res, stats, rounds

    return run

"""Bulk Synchronous Parallel execution models (paper baselines, Fig. 1 a/b).

``run_bsp_fixed``  — fixed-timestep lockstep execution with a communication
window equal to the minimum synaptic delay (0.1 ms): methods 1a (cnexp),
1b (euler), 2a (derivimplicit).

``run_bsp_vardt`` — NEURON-style BSP variable-timestep (method 2b): each
neuron runs its own CVODE/BDF integrator but a collective barrier clamps all
integrators to the communication-window boundary; spikes are exchanged at the
barrier.  The window clamp is precisely why variable-step struggles under
BSP: steps can never grow beyond the global 0.1 ms interval even in quiet
regimes (paper §4.3).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import sched
from repro.core import bdf
from repro.core import events as ev
from repro.core import exec_common as xc
from repro.core.cell import CellModel
from repro.core.fixed_step import make_stepper
from repro.core.network import Network

EV_CAP = 64
SPK_CAP = 256


class RunResult(NamedTuple):
    rec: ev.SpikeRecord
    n_steps: jnp.ndarray       # total interpolation steps (all neurons)
    n_events: jnp.ndarray      # delivered events
    n_resets: jnp.ndarray      # IVP resets (vardt only)
    dropped: jnp.ndarray       # event-queue overflow (must be 0)
    failed: jnp.ndarray        # integrator failures (must be 0)
    y_final: jnp.ndarray       # [N, n_state] (vardt: zn[0])
    sched: object = None       # xc.SchedStats active-set telemetry (vardt
                               # runners; None where not collected)
    comm: object = None        # transport telemetry dict (run_fap_spmd:
                               # realized parcel bytes / class counts;
                               # None on single-host runners)
    solver: object = None      # solver telemetry dict summed over lanes
                               # (vardt runners: nst/nni/nfe/nsetups/netf/
                               # nncf — nsetups/nni is the Jacobian-reuse
                               # ratio of the freshness policy)
    health: object = None      # robustness telemetry dict (checkpointed
                               # drivers: watchdog checks/rollbacks,
                               # resume round, straggler stats, escalated
                               # drop counters — exec_common.empty_health;
                               # None on the fast jitted paths)


def make_bsp_fixed_runner(model: CellModel, net: Network, iinj, t_end: float,
                          method: str = "cnexp", dt: float = 0.025,
                          window: float = 0.1, ev_cap: int = EV_CAP,
                          queue: str = "dense",
                          wheel: sched.WheelSpec = sched.WheelSpec(),
                          fanout: str = "dense", spike_cap: int = 0):
    n = net.n
    dnet = xc.to_device(net)
    qops = sched.get_queue_ops(queue, ev_cap=ev_cap, wheel=wheel)
    qinsert = sched.edge_insert(qops, net)
    spike_ins = xc.make_spike_insert(net, dnet, qops, qinsert, fanout,
                                     spike_cap)
    steps_w = max(1, int(round(window / dt)))
    n_windows = int(math.ceil(t_end / (steps_w * dt)))
    step = make_stepper(model, method, dt)
    vstep = jax.vmap(step)
    iinj = jnp.broadcast_to(jnp.asarray(iinj, jnp.float64), (n,))

    def window_body(carry, w_idx):
        Y, eq, rec, n_ev = carry
        t0 = w_idx * (steps_w * dt)

        def step_body(c, j):
            Y, eq, rec, n_ev = c
            t_j = t0 + j * dt
            # deliver all events due by this step boundary (fixed-step grid)
            eq, wa, wg, cnt = qops.deliver_until(eq, jnp.full((n,), t_j + dt))
            Y = jax.vmap(model.apply_event)(Y, wa, wg)
            v_prev = Y[:, model.idx_vsoma]
            Y = vstep(Y, iinj)
            v_new = Y[:, model.idx_vsoma]
            spiked, t_sp = xc.detect_spikes(v_prev, v_new, t_j, t_j + dt)
            rec = ev.record_spikes(rec, jnp.arange(n), t_sp, spiked)
            return (Y, eq, rec, n_ev + cnt.sum(dtype=jnp.int32)), (spiked, t_sp)

        (Y, eq, rec, n_ev), (spk, tsp) = jax.lax.scan(
            step_body, (Y, eq, rec, n_ev), jnp.arange(steps_w))
        # collective exchange at the window barrier (<=1 spike per 0.1 ms)
        spiked_w = spk.any(axis=0)
        t_spike_w = jnp.where(spk, tsp, 0.0).sum(axis=0)
        eq = spike_ins(eq, spiked_w, t_spike_w)
        return (Y, eq, rec, n_ev), None

    @jax.jit
    def run():
        Y = xc.batch_init(model, n)
        eq = qops.make(n)
        rec = ev.make_spike_record(n, SPK_CAP)
        (Y, eq, rec, n_ev), _ = jax.lax.scan(
            window_body, (Y, eq, rec, jnp.zeros((), jnp.int32)),
            jnp.arange(n_windows))
        n_steps = jnp.asarray(n * n_windows * steps_w)
        z = jnp.zeros((), jnp.int32)
        return RunResult(rec, n_steps, n_ev, z, eq.dropped,
                         jnp.zeros((), bool), Y)

    return run


def run_bsp_fixed(*args, **kw) -> RunResult:
    return make_bsp_fixed_runner(*args, **kw)()


# ---------------------------------------------------------------------------
# variable-timestep neuron advance, shared by BSP-vardt and FAP-vardt
# ---------------------------------------------------------------------------
def make_vardt_advance(model: CellModel, opts: bdf.BDFOptions,
                       eg_window: float = 0.0, step_budget: int = 12):
    """Per-neuron advance to a horizon with exact (or grouped) event delivery.

    Returns fn(st, eq_t, eq_a, eq_g, horizon, active, iinj)
        -> (st, eq_t, spiked, t_spike, n_deliv, n_resets)
    designed for vmap over neurons.  Non-speculative: the BDF step is clamped
    (tstop) at min(horizon, next event time) so no step ever crosses an event.

    The deliver/step branches are fused through ``bdf.step_or_deliver``:
    one rhs + Hines-solve stream per loop iteration serves both (the
    delivery reset's rhs is the Newton corrector's first evaluation)
    instead of each branch paying its own full evaluation per iteration.
    """

    def advance(st: bdf.BDFState, eq_t, eq_a, eq_g, horizon, active, iinj_n):
        def body(i, c):
            st, eq_t, spiked, t_sp, nd, nrs = c
            run = jnp.logical_and(active, st.t < horizon - 1e-12)
            due = eq_t.min()
            deliver_now = jnp.logical_and(run, due <= st.t + 1e-12)
            step_now = jnp.logical_and(run, ~deliver_now)

            # --- grouped delivery weights at current time -----------------
            mask = eq_t <= due + eg_window + 1e-12
            wa = jnp.sum(jnp.where(mask, eq_a, 0.0))
            wg = jnp.sum(jnp.where(mask, eq_g, 0.0))

            # --- fused: event reset OR one BDF step clamped at the horizon
            # / next event — a single shared evaluation stream -------------
            t_lim = jnp.minimum(horizon, due)
            v_prev = st.zn[0][model.idx_vsoma]
            t_prev = st.t
            st_n = bdf.step_or_deliver(model, st, t_lim, wa, wg, deliver_now,
                                       iinj_n, opts)
            sp, tsp = xc.detect_spikes(v_prev, st_n.zn[0][model.idx_vsoma],
                                       t_prev, st_n.t)

            st = jax.tree_util.tree_map(
                lambda n_, o: jnp.where(run, n_, o), st_n, st)
            eq_t = jnp.where(deliver_now, jnp.where(mask, jnp.inf, eq_t),
                             eq_t)
            new_spike = jnp.logical_and(step_now, sp)
            spiked = jnp.logical_or(spiked, new_spike)
            t_sp = jnp.where(new_spike, tsp, t_sp)
            nd = nd + jnp.where(deliver_now, mask.sum(dtype=jnp.int32), 0)
            nrs = nrs + jnp.where(deliver_now, 1, 0)
            return st, eq_t, spiked, t_sp, nd, nrs

        z = jnp.zeros((), jnp.int32)
        init = (st, eq_t, jnp.zeros((), bool), jnp.zeros(()), z, z)
        return jax.lax.fori_loop(0, step_budget, body, init)

    return advance


def make_bsp_vardt_runner(model: CellModel, net: Network, iinj, t_end: float,
                          opts: bdf.BDFOptions = bdf.BDFOptions(),
                          eg_window: float = 0.0, window: float = 0.1,
                          step_budget: int = 8, ev_cap: int = EV_CAP,
                          queue: str = "dense",
                          wheel: sched.WheelSpec = sched.WheelSpec(),
                          batch: str = "dense", batch_cap: int = 0,
                          n_bisect: int = 48, fanout: str = "dense",
                          spike_cap: int = 0):
    """Method 2b: CVODE under BSP — barrier at every communication window.

    batch: "dense" vmaps the vardt advance over all N neurons per window;
    "compact" gathers only the lanes still behind the barrier into
    ``batch_cap``-wide dispatches (compact -> step -> scatter), chunking
    the window's frontier by earliest clock.  Every lane is advanced
    exactly once per window with the same barrier horizon either way, so
    compact spike trains are event-for-event identical to dense at ANY
    cap (chunks, unlike the FAP round's roll-over, never change a lane's
    horizon).  batch_cap <= 0 means N.

    fanout: "dense" | "compact" — the spike-delivery twin of ``batch``
    (``exec_common.make_spike_insert``): "compact" gathers only the
    spiking lanes' out-edges per window; spike_cap overflow falls back to
    the dense branch, never drops.  spike_cap <= 0 means min(N, 256).
    """
    if batch not in ("dense", "compact"):
        raise ValueError(f"unknown batch mode {batch!r}")
    n = net.n
    cap = n if batch_cap <= 0 else min(int(batch_cap), n)
    dnet = xc.to_device(net)
    qops = sched.get_queue_ops(queue, ev_cap=ev_cap, wheel=wheel)
    qinsert = sched.edge_insert(qops, net)
    spike_ins = xc.make_spike_insert(net, dnet, qops, qinsert, fanout,
                                     spike_cap)
    n_windows = int(math.ceil(t_end / window))
    iinj = jnp.broadcast_to(jnp.asarray(iinj, jnp.float64), (n,))
    advance = make_vardt_advance(model, opts, eg_window, step_budget)
    vadvance = jax.vmap(advance)
    neuron_ids = jnp.arange(n, dtype=jnp.int32)     # hoisted round constant

    def window_body(carry, w_idx):
        sts, eq, rec, n_ev, n_rs, stats = carry
        w_end = (w_idx + 1.0) * window
        horizon = jnp.full((n,), 1.0) * w_end          # global barrier
        behind = sts.t < w_end - 1e-12
        n_behind = behind.sum(dtype=jnp.int64)

        if batch == "compact":
            def chunk_cond(c):
                return c[-1].any()

            def chunk_body(c):
                sts, eq, spiked, t_sp, nd, nrs, stepped, disp, todo = c
                ids, _ = xc.compact_frontier(todo, sts.t, cap, n_bisect)
                lane_ok = ids < n
                idc = jnp.minimum(ids, n - 1)
                sts_b = xc.gather_lanes(sts, idc)
                eqt_b, eqa_b, eqg_b = sched.gather_rows(eq, idc)
                sts_b, eqt_b, spk_b, tsp_b, nd_b, nrs_b = vadvance(
                    sts_b, eqt_b, eqa_b, eqg_b, horizon[idc], lane_ok,
                    iinj[idc])
                sts = xc.scatter_lanes(sts, sts_b, ids)
                eq = sched.scatter_rows(eq, ids, eqt_b)
                spiked = xc.scatter_at(spiked, ids, spk_b)
                t_sp = xc.scatter_at(t_sp, ids, tsp_b)
                todo = xc.scatter_at(todo, ids, False)
                return (sts, eq, spiked, t_sp,
                        nd + nd_b.sum(dtype=jnp.int32),
                        nrs + nrs_b.sum(dtype=jnp.int32),
                        stepped + lane_ok.sum(dtype=jnp.int64),
                        disp + 1, todo)

            z = jnp.zeros((), jnp.int32)
            sts, eq, spiked, t_sp, nd, nrs, stepped, disp, _ = \
                jax.lax.while_loop(chunk_cond, chunk_body,
                                   (sts, eq, jnp.zeros((n,), bool),
                                    jnp.zeros((n,)), z, z,
                                    jnp.zeros((), jnp.int64), z, behind))
            stats = xc.SchedStats(stats.runnable + n_behind,
                                  stats.stepped + stepped,
                                  stats.lanes + disp.astype(jnp.int64) * cap,
                                  stats.rounds + disp)
        else:
            active = jnp.ones((n,), bool)
            sts, eq_t, spiked, t_sp, nd, nrs = vadvance(
                sts, eq.t, eq.w_ampa, eq.w_gaba, horizon, active, iinj)
            eq = eq._replace(t=eq_t)
            nd, nrs = nd.sum(dtype=jnp.int32), nrs.sum(dtype=jnp.int32)
            stats = xc.SchedStats(stats.runnable + n_behind,
                                  stats.stepped + n_behind,
                                  stats.lanes + n,
                                  stats.rounds + 1)
        rec = ev.record_spikes(rec, neuron_ids, t_sp, spiked)
        eq = spike_ins(eq, spiked, t_sp)
        return (sts, eq, rec, n_ev + nd, n_rs + nrs, stats), None

    def init_carry():
        Y = xc.batch_init(model, n)
        sts = jax.vmap(lambda y, i: bdf.reinit(model, 0.0, y, i, opts))(Y, iinj)
        eq = qops.make(n)
        rec = ev.make_spike_record(n, SPK_CAP)
        return (sts, eq, rec, jnp.zeros((), jnp.int32),
                jnp.zeros((), jnp.int32), xc.SchedStats.zeros())

    @jax.jit
    def _run():
        (sts, eq, rec, n_ev, n_rs, stats), _ = jax.lax.scan(
            window_body, init_carry(), jnp.arange(n_windows))
        return RunResult(rec, sts.nst.sum(), n_ev, n_rs, eq.dropped,
                         sts.failed.any(), sts.zn[:, 0], stats,
                         solver=xc.solver_stats(sts))

    jwindow = None

    def run(checkpoint_every: int = 0, ckpt_dir=None, resume: bool = False,
            fault=None, watchdog=None, max_rollbacks: int = 2,
            ckpt_keep: int = 3):
        """Nullary fast path; any robustness knob switches to the
        host-stepped checkpointed driver (``exec_common.run_checkpointed``)
        — one jitted window per host iteration, window index carried in
        ``counters["rounds"]``.  Kill/resume and rollback runs are
        bit-identical to the uninterrupted host-stepped run (one shared
        compiled window); vs the scanned fast path agreement is to
        floating-point ulp (XLA fuses the scan body differently)."""
        robust = bool(checkpoint_every or resume or watchdog
                      or fault is not None)
        if not robust:
            return _run()
        nonlocal jwindow
        if jwindow is None:     # compile once; reused across run() calls
            jwindow = jax.jit(lambda c, w: window_body(c, w)[0])
        if watchdog is None:
            watchdog = True

        def pack(c):
            sts, eq, rec, n_ev, n_rs, stats = c[:6]
            w = c[6] if len(c) > 6 else jnp.zeros((), jnp.int64)
            return xc.SimCarry(sts, eq, rec, (), {
                "n_ev": n_ev, "n_rs": n_rs, "stats": stats, "rounds": w})

        def step_fn(sc):
            c = jwindow((sc.sts, sc.eq, sc.rec, sc.counters["n_ev"],
                         sc.counters["n_rs"], sc.counters["stats"]),
                        sc.counters["rounds"])
            return pack(c + (sc.counters["rounds"] + 1,))

        sc, health = xc.run_checkpointed(
            lambda: pack(init_carry()), step_fn,
            lambda sc: int(sc.counters["rounds"]) < n_windows,
            ckpt_dir=ckpt_dir, checkpoint_every=checkpoint_every,
            resume=resume, keep=ckpt_keep, fault=fault,
            health_of=((lambda sc, t_prev: xc.health_check(sc.sts, t_prev))
                       if watchdog else None),
            max_rollbacks=max_rollbacks)
        sts, eq, rec = sc.sts, sc.eq, sc.rec
        health["dropped_events"] = int(eq.dropped)
        return RunResult(rec, sts.nst.sum(), sc.counters["n_ev"],
                         sc.counters["n_rs"], eq.dropped,
                         jnp.logical_or(sts.failed.any(),
                                        health["rollback_exhausted"]),
                         sts.zn[:, 0], sc.counters["stats"],
                         solver=xc.solver_stats(sts), health=health)

    return run


def run_bsp_vardt(*args, **kw) -> RunResult:
    return make_bsp_vardt_runner(*args, **kw)()

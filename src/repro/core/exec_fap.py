"""Fully-Asynchronous Parallel execution models (the paper's contribution).

``make_fap_fixed_runner``  — method 1c: fixed-timestep FAP from the authors'
previous work [2]: per-neuron clocks advance to the pairwise dependency
horizon; no global barrier.

``make_fap_vardt_runner`` — method 2c (THIS paper, Fig. 1d / Fig. 4 right):
non-speculative scheduled variable-timestep stepping.  Each round:

  1. horizon[i] = min over in-edges (t[pre] + delay)   (stepping-notification
     map; scatter-min over the static edge list — DESIGN.md §3),
  2. the *earliest neurons step next*: every neuron strictly behind its
     horizon advances (optionally restricted to the K earliest — the
     scheduler knob), each by its own variable-order variable-step BDF,
     clamped at min(horizon, next event) => exhaustive, never speculative,
  3. spikes fan out as events (t_spike + delay) into destination queues.

Event grouping (eg_window = dt/2 or dt) reproduces the paper's 2c variants.

The conservative-lookahead argument of the paper holds here: delays are
>= min_delay > 0, so the globally earliest neuron always has
horizon > t — every round makes progress and no deadlock or backstepping can
occur.  ``tests/test_property_fap.py`` checks this invariant by construction.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import sched
from repro.core import bdf
from repro.core import events as ev
from repro.core import exec_common as xc
from repro.core.cell import CellModel
from repro.core.exec_bsp import EV_CAP, SPK_CAP, RunResult, make_vardt_advance
from repro.core.fixed_step import make_stepper
from repro.core.network import Network
from repro.kernels.event_wheel import ops as ew_ops


def make_fap_fixed_runner(model: CellModel, net: Network, iinj, t_end: float,
                          method: str = "cnexp", dt: float = 0.025,
                          round_cap_steps: int = 16, ev_cap: int = EV_CAP,
                          max_rounds: int = 2_000_000, queue: str = "dense",
                          wheel: sched.WheelSpec = sched.WheelSpec(),
                          fanout: str = "dense", spike_cap: int = 0):
    """Fixed-step FAP (method 1c).  Returns a nullary jitted runner."""
    n = net.n
    dnet = xc.to_device(net)
    qops = sched.get_queue_ops(queue, ev_cap=ev_cap, wheel=wheel)
    qinsert = sched.edge_insert(qops, net)
    spike_ins = xc.make_spike_insert(net, dnet, qops, qinsert, fanout,
                                     spike_cap)
    step = make_stepper(model, method, dt)
    vstep = jax.vmap(step)
    iinj_v = jnp.broadcast_to(jnp.asarray(iinj, jnp.float64), (n,))
    neuron_ids = jnp.arange(n, dtype=jnp.int32)     # hoisted round constant
    n_total_steps = int(round(t_end / dt))

    def round_body(carry):
        Y, k, eq, rec, n_ev, n_st, rounds = carry
        t_clock = k * dt
        horizon = xc.horizon_times(dnet, n, t_clock, t_end)
        # whole fixed steps available below the horizon, capped per round
        n_adv = jnp.clip(jnp.floor((horizon - t_clock) / dt + 1e-9).astype(jnp.int32),
                         0, round_cap_steps)
        spiked_r = jnp.zeros((n,), bool)
        t_sp_r = jnp.zeros((n,))

        def inner(j, c):
            Y, k, eq, rec, n_ev, n_st, spiked_r, t_sp_r = c
            act = j < n_adv
            t_j = k * dt
            eq2, wa, wg, cnt = qops.deliver_until(eq, jnp.where(act, t_j + dt, -jnp.inf))
            Y2 = jax.vmap(model.apply_event)(Y, wa, wg)
            v_prev = Y2[:, model.idx_vsoma]
            Y2 = vstep(Y2, iinj_v)
            sp, tsp = xc.detect_spikes(v_prev, Y2[:, model.idx_vsoma], t_j, t_j + dt)
            sp = jnp.logical_and(sp, act)
            Y = jnp.where(act[:, None], Y2, Y)
            k = jnp.where(act, k + 1, k)
            spiked_r = jnp.logical_or(spiked_r, sp)
            t_sp_r = jnp.where(sp, tsp, t_sp_r)
            rec = ev.record_spikes(rec, neuron_ids, tsp, sp)
            return (Y, k, eq2, rec, n_ev + cnt.sum(dtype=jnp.int32), n_st + act.sum(dtype=jnp.int32), spiked_r, t_sp_r)

        Y, k, eq, rec, n_ev, n_st, spiked_r, t_sp_r = jax.lax.fori_loop(
            0, round_cap_steps, inner,
            (Y, k, eq, rec, n_ev, n_st, spiked_r, t_sp_r))
        eq = spike_ins(eq, spiked_r, t_sp_r)
        return Y, k, eq, rec, n_ev, n_st, rounds + 1

    def cond(carry):
        _, k, _, _, _, _, rounds = carry
        return jnp.logical_and((k.min() < n_total_steps), rounds < max_rounds)

    @jax.jit
    def run():
        Y = xc.batch_init(model, n)
        eq = qops.make(n)
        rec = ev.make_spike_record(n, SPK_CAP)
        z = jnp.zeros((), jnp.int32)
        Y, k, eq, rec, n_ev, n_st, rounds = jax.lax.while_loop(
            cond, round_body, (Y, jnp.zeros((n,), jnp.int32), eq, rec, z, z, z))
        return RunResult(rec, n_st, n_ev, z, eq.dropped,
                         jnp.zeros((), bool), Y), rounds

    return run


def make_fap_vardt_runner(model: CellModel, net: Network, iinj, t_end: float,
                          opts: bdf.BDFOptions = bdf.BDFOptions(),
                          eg_window: float = 0.0, horizon_cap: float = 2.0,
                          k_select: int = 0, step_budget: int = 12,
                          ev_cap: int = EV_CAP, max_rounds: int = 1_000_000,
                          queue: str = "dense",
                          wheel: sched.WheelSpec = sched.WheelSpec(),
                          select: str = "sort", horizon_impl: str = "scatter",
                          n_bisect: int = 48, batch: str = "dense",
                          batch_cap=0, fanout: str = "dense",
                          spike_cap: int = 0, probe_t: float = 5.0):
    """Variable-step FAP (method 2c, the paper's reference method).

    eg_window: 0 -> precise delivery (2c-);  dt/2 or dt -> grouped variants.
    k_select:  0 -> all runnable neurons advance each round; K>0 restricts to
               the K earliest (the explicit scheduler of paper §2.4).
    horizon_cap bounds per-round advancement (ms) so one spike per neuron per
    round is guaranteed (ISI >> cap at all five regimes).
    queue:        "dense" (argsort slot queue) or "wheel" (bucketed event
                  wheel, O(E) scatter insert — repro.sched).
    select:       "sort" (kth via jnp.sort) or "threshold" (bisection on
                  counts — no sort primitive in the round's jaxpr).
    horizon_impl: "scatter" (edge scatter-min) or "fused" (Pallas kernel
                  over the static by-post layout — kernels/event_wheel).
    batch:        "dense" vmaps the step machinery over all N neurons every
                  round; "compact" compacts the runnable mask into a
                  gather-id list, advances only a fixed-size [batch_cap]
                  batch and scatters results back — per-round stepping
                  cost O(batch_cap * step_budget) instead of
                  O(N * step_budget).  When the frontier overflows
                  batch_cap the earliest-clock neurons are kept
                  (``select_threshold`` bisection; the globally earliest
                  neuron is always included, preserving the conservative-
                  lookahead progress argument) and overflowed neurons
                  roll to the next round.  batch_cap <= 0 means N;
                  batch_cap="auto" runs a short dense probe
                  (min(t_end, probe_t) ms) and picks the cap from the
                  measured frontier occupancy
                  (``exec_common.auto_batch_cap``; the chosen value is
                  exposed as ``run.batch_cap``) — one extra compile.
                  Two further compact-only structural savings keep the
                  round ~flat in N at fixed cap: the O(E) fan-out/insert
                  runs under a ``lax.cond`` (a semantic no-op on
                  spike-free rounds, the common case off the burst
                  regimes), and with the scatter horizon on a grouped net
                  the dependency horizon is maintained *incrementally* —
                  only rows whose pre clocks moved (the batch's
                  out-neighbours) are recomputed, bit-identical to the
                  full scatter-min because min is exact in fp.
    fanout:       "dense" fans every spike over all E edges; "compact"
                  gathers only the <= spike_cap spiking lanes' out-edges
                  (static ``out_edge_table`` rows via the
                  ``compact_gather`` kernel) and inserts that fixed
                  [spike_cap * k_out] batch — bursty regimes stop paying
                  O(E) per spiking round.  More spikes than spike_cap
                  fall back to the dense branch (identical events,
                  never a drop).  spike_cap <= 0 defaults to the batch
                  cap under batch="compact" (stepped lanes bound spikes,
                  so the fallback never fires) and min(N, 256) otherwise.
                  spike_cap="auto" sizes the cap from the probe run's
                  spike-rate telemetry (``exec_common.auto_spike_cap``;
                  exposed as ``run.spike_cap``) — the probe is shared
                  with batch_cap="auto" when both are requested.

    The returned nullary runner also exposes ``run.init_carry`` /
    ``run.round_body`` / ``run.cond`` so benchmarks can drive and time
    single scheduler rounds.
    """
    n = net.n
    if batch not in ("dense", "compact"):
        raise ValueError(f"unknown batch mode {batch!r}")
    if batch_cap == "auto" or spike_cap == "auto":
        # one dense probe run serves both auto caps
        probe = make_fap_vardt_runner(
            model, net, iinj, min(t_end, probe_t), opts=opts,
            eg_window=eg_window, horizon_cap=horizon_cap,
            k_select=k_select, step_budget=step_budget, ev_cap=ev_cap,
            max_rounds=max_rounds, queue=queue, wheel=wheel, select=select,
            horizon_impl=horizon_impl, n_bisect=n_bisect)
        pres, _ = probe()
        if batch_cap == "auto":
            batch_cap = xc.auto_batch_cap(pres.sched, n)
        if spike_cap == "auto":
            spike_cap = xc.auto_spike_cap(pres.rec, pres.sched, n)
    cap = n if batch_cap <= 0 else min(int(batch_cap), n)
    s_cap = spike_cap if spike_cap > 0 else \
        (cap if batch == "compact" else min(n, 256))
    dnet = xc.to_device(net)
    iinj_v = jnp.broadcast_to(jnp.asarray(iinj, jnp.float64), (n,))
    neuron_ids = jnp.arange(n, dtype=jnp.int32)     # hoisted round constant
    advance = make_vardt_advance(model, opts, eg_window, step_budget)
    vadvance = jax.vmap(advance)
    qops = sched.get_queue_ops(queue, ev_cap=ev_cap, wheel=wheel)
    qinsert = sched.edge_insert(qops, net)
    if select not in ("sort", "threshold"):
        raise ValueError(f"unknown select {select!r}")
    if horizon_impl == "fused":
        pre_byk, delay_byk = ew_ops.by_post_layout(net)
    elif horizon_impl != "scatter":
        raise ValueError(f"unknown horizon_impl {horizon_impl!r}")
    # incremental horizon maintenance: compact + scatter impl + grouped net
    incremental = (batch == "compact" and horizon_impl == "scatter"
                   and sched.grouped_k(net) is not None)
    edge_tbl = None
    if incremental:
        pre_byk, delay_byk = ew_ops.by_post_layout(net)
        post_np, edge_np = xc.out_tables(net)    # one grouping pass serves
        out_post = jnp.asarray(post_np)          # the horizon ([N,MO], sent.
        if fanout == "compact":                  # n) and the fan-out tables
            edge_tbl = edge_np

    def _horizon_rows(t_clock, p):
        """Recompute horizon for the (sentinel-padded) post set ``p`` from
        current clocks — the same min/clamp chain as the full scatter-min
        (min is exact, so incremental == full, bitwise)."""
        pc = jnp.minimum(p, n - 1)
        cand = t_clock[pre_byk[:, pc]] + delay_byk[:, pc]     # [K, |p|]
        hor_p = jnp.minimum(jnp.min(cand, axis=0), t_end)
        return jnp.minimum(hor_p, t_clock[pc] + horizon_cap)

    spike_ins = xc.make_spike_insert(net, dnet, qops, qinsert, fanout, s_cap,
                                     edge_table=edge_tbl)

    def _insert_spikes(eq, spiked_b, tsp_b, ids):
        spiked = xc.scatter_at(jnp.zeros((n,), bool), ids, spiked_b)
        t_sp = xc.scatter_at(jnp.zeros((n,)), ids, tsp_b)
        return spike_ins(eq, spiked, t_sp)

    def _round(carry, iinj_r, active=None, k_qos=None):
        """One scheduler round.  ``iinj_r`` is the per-neuron stimulus as a
        traced argument (the legacy path closes over the construction-time
        value — identical jaxpr); ``active``/``k_qos`` are the multi-tenant
        serving hooks (``repro.serve``): a scalar bool that masks the whole
        lane out of the round (a quarantined or idle tenant — the round is
        then a semantic no-op on its state, which is what makes the
        tenant's trajectory independent of the service's activity
        schedule) and a traced earliest-``k`` frontier restriction (the
        per-tenant QoS cap; 0 = unlimited).  Both default to None, which
        traces nothing extra — the single-tenant runners are untouched."""
        if incremental:
            sts, eq, rec, horizon, n_ev, n_rs, stats, rounds = carry
        else:
            sts, eq, rec, n_ev, n_rs, stats, rounds = carry
        t_clock = sts.t
        if incremental:
            runnable = xc.runnable_mask(t_clock, horizon)
        elif horizon_impl == "fused":
            # fused kernel: min over in-edges + clamps + runnable (+ the
            # earliest-K threshold when selection is sort-free too)
            horizon, runnable = ew_ops.fused_horizon_select(
                t_clock, pre_byk, delay_byk, t_end=t_end,
                horizon_cap=horizon_cap, n_iters=n_bisect,
                k_select=k_select if select == "threshold" else 0)
        else:
            horizon = xc.horizon_times(dnet, n, t_clock, t_end,
                                       horizon_cap=horizon_cap)
            runnable = xc.runnable_mask(t_clock, horizon)
        if k_select > 0 and select == "threshold" and \
                (incremental or horizon_impl == "scatter"):
            score = jnp.where(runnable, t_clock, jnp.inf)
            tau = ew_ops.select_threshold(score, k_select, n_iters=n_bisect)
            runnable = jnp.logical_and(runnable, score <= tau)
        if k_select > 0 and select == "sort":
            # earliest-neuron-steps-next: keep only the K earliest runnable
            score = jnp.where(runnable, t_clock, jnp.inf)
            kth = jnp.sort(score)[min(k_select, n) - 1]
            runnable = jnp.logical_and(runnable, score <= kth)
        if active is not None:
            # tenant-lane mask: an inactive lane advances nothing
            runnable = jnp.logical_and(runnable, active)
        if k_qos is not None:
            # per-tenant QoS frontier cap: restrict to the k_qos earliest
            # runnable neurons (traced k — one compiled round serves every
            # class); k_qos <= 0 selects everything (tau = max finite)
            score = jnp.where(runnable, t_clock, jnp.inf)
            k_eff = jnp.where(k_qos > 0, jnp.minimum(k_qos, n), n)
            tau = ew_ops.select_threshold(score, k_eff, n_iters=n_bisect)
            runnable = jnp.logical_and(runnable, score <= tau)
        n_runnable = runnable.sum(dtype=jnp.int64)

        if batch == "compact":
            # --- compact -> step -> scatter: only the runnable frontier
            # pays the step machinery ----------------------------------
            ids, _ = xc.compact_frontier(runnable, t_clock, cap, n_bisect)
            lane_ok = ids < n
            idc = jnp.minimum(ids, n - 1)
            sts_b = xc.gather_lanes(sts, idc)
            t_b_prev = sts_b.t
            eqt_b, eqa_b, eqg_b = sched.gather_rows(eq, idc)
            sts_b, eqt_b, spiked_b, tsp_b, nd, nrs = vadvance(
                sts_b, eqt_b, eqa_b, eqg_b, horizon[idc], lane_ok,
                iinj_r[idc])
            sts = xc.scatter_lanes(sts, sts_b, ids)
            eq = sched.scatter_rows(eq, ids, eqt_b)
            rec = ev.record_spikes(rec, ids, tsp_b, spiked_b)
            # O(E) fan-out + insert only on rounds that actually spiked
            # (identical either way: zero spikes insert nothing)
            eq = jax.lax.cond(spiked_b.any(), _insert_spikes,
                              lambda eq, *_: eq, eq, spiked_b, tsp_b, ids)
            if incremental:
                # only rows fed by a moved clock can change: the batch's
                # out-neighbours, plus the batch lanes' own cap terms
                moved = jnp.logical_and(lane_ok, sts_b.t != t_b_prev)
                outp = jnp.where(moved[:, None], out_post[idc], n)
                p = jnp.concatenate([ids, outp.reshape(-1)])
                horizon = horizon.at[p].set(_horizon_rows(sts.t, p),
                                            mode="drop")
            stats = xc.SchedStats(stats.runnable + n_runnable,
                                  stats.stepped + lane_ok.sum(dtype=jnp.int64),
                                  stats.lanes + cap,
                                  stats.rounds + 1)
        else:
            sts, eq_t, spiked, t_sp, nd, nrs = vadvance(
                sts, eq.t, eq.w_ampa, eq.w_gaba, horizon, runnable, iinj_r)
            eq = eq._replace(t=eq_t)
            rec = ev.record_spikes(rec, neuron_ids, t_sp, spiked)
            eq = spike_ins(eq, spiked, t_sp)
            stats = xc.SchedStats(stats.runnable + n_runnable,
                                  stats.stepped + n_runnable,
                                  stats.lanes + n,
                                  stats.rounds + 1)
        out = (sts, eq, rec, n_ev + nd.sum(dtype=jnp.int32),
               n_rs + nrs.sum(dtype=jnp.int32), stats, rounds + 1)
        if incremental:
            out = out[:3] + (horizon,) + out[3:]
        return out

    def round_body(carry):
        return _round(carry, iinj_v)

    def tenant_round(carry, iinj, active, k_qos=0):
        """Round with call-time stimulus + lane mask + QoS frontier cap —
        the per-tenant unit ``repro.serve`` vmaps over its lane axis
        (in_axes=(0, 0, 0, 0): carry leaves [T, ...], iinj [T, N],
        active bool[T], k_qos i32[T])."""
        i = jnp.broadcast_to(jnp.asarray(iinj, jnp.float64), (n,))
        return _round(carry, i, active, k_qos)

    def cond(carry):
        sts, rounds = carry[0], carry[-1]
        return jnp.logical_and(sts.t.min() < t_end - 1e-9,
                               jnp.logical_and(rounds < max_rounds,
                                               ~sts.failed.any()))

    def init_carry(iinj=None):
        """Fresh round-0 carry; ``iinj`` overrides the construction-time
        stimulus (per-tenant admission in ``repro.serve``)."""
        iv = iinj_v if iinj is None else \
            jnp.broadcast_to(jnp.asarray(iinj, jnp.float64), (n,))
        Y = xc.batch_init(model, n)
        sts = jax.vmap(lambda y, i: bdf.reinit(model, 0.0, y, i, opts))(Y, iv)
        eq = qops.make(n)
        rec = ev.make_spike_record(n, SPK_CAP)
        z = jnp.zeros((), jnp.int32)
        carry = sts, eq, rec, z, z, xc.SchedStats.zeros(), z
        if incremental:
            hor0 = xc.horizon_times(dnet, n, sts.t, t_end,
                                    horizon_cap=horizon_cap)
            carry = carry[:3] + (hor0,) + carry[3:]
        return carry

    @jax.jit
    def _run():
        out = jax.lax.while_loop(cond, round_body, init_carry())
        if incremental:
            sts, eq, rec, _, n_ev, n_rs, stats, rounds = out
        else:
            sts, eq, rec, n_ev, n_rs, stats, rounds = out
        return RunResult(rec, sts.nst.sum(), n_ev, n_rs, eq.dropped,
                         sts.failed.any(), sts.zn[:, 0], stats,
                         solver=xc.solver_stats(sts)), rounds

    # --- preemption tolerance: SimCarry <-> round-carry packing ----------
    # hcarry holds the incremental horizon (mesh/layout-dependent); all
    # solver/sched counters ride a dict so repro.checkpoint snapshots the
    # WHOLE round state leaf-for-leaf.
    def pack(c):
        if incremental:
            sts, eq, rec, horizon, n_ev, n_rs, stats, rounds = c
            h = (horizon,)
        else:
            sts, eq, rec, n_ev, n_rs, stats, rounds = c
            h = ()
        return xc.SimCarry(sts, eq, rec, h, {
            "n_ev": n_ev, "n_rs": n_rs, "stats": stats, "rounds": rounds})

    def unpack(sc):
        c = (sc.sts, sc.eq, sc.rec, sc.counters["n_ev"],
             sc.counters["n_rs"], sc.counters["stats"],
             sc.counters["rounds"])
        return c[:3] + tuple(sc.hcarry) + c[3:] if incremental else c

    jround = None

    def run(checkpoint_every: int = 0, ckpt_dir=None, resume: bool = False,
            fault=None, watchdog=None, max_rollbacks: int = 2,
            ckpt_keep: int = 3):
        """Nullary fast path (jitted ``while_loop``); any robustness knob
        switches to the host-stepped checkpointed driver.  Knobs are
        call-time so ONE runner (one ``jax.jit(round_body)`` compile)
        serves many kill/resume/poison scenarios — the Hypothesis
        property tests depend on this.  Within the host-stepped mode
        every run shares that one compiled round, so kill/resume and
        watchdog-rollback runs are event-for-event IDENTICAL to the
        uninterrupted host-stepped run; against the ``while_loop`` fast
        path agreement is to floating-point ulp only (XLA fuses the
        standalone round differently than the loop body)."""
        robust = bool(checkpoint_every or resume or watchdog
                      or fault is not None)
        if not robust:
            return _run()
        nonlocal jround
        if jround is None:      # compile once; reused across run() calls
            jround = jax.jit(round_body)
        if watchdog is None:
            watchdog = True

        health_of = None
        if watchdog:
            def health_of(sc, t_prev):
                return xc.health_check(
                    sc.sts, t_prev,
                    horizon=sc.hcarry[0] if incremental else None,
                    horizon_cap=horizon_cap)

        sc, health = xc.run_checkpointed(
            lambda: pack(init_carry()),
            lambda sc: pack(jround(unpack(sc))),
            lambda sc: bool(cond(unpack(sc))),
            ckpt_dir=ckpt_dir, checkpoint_every=checkpoint_every,
            resume=resume, keep=ckpt_keep, fault=fault,
            health_of=health_of, max_rollbacks=max_rollbacks)
        sts, eq, rec = sc.sts, sc.eq, sc.rec
        health["dropped_events"] = int(eq.dropped)
        res = RunResult(rec, sts.nst.sum(), sc.counters["n_ev"],
                        sc.counters["n_rs"], eq.dropped,
                        jnp.logical_or(sts.failed.any(),
                                       health["rollback_exhausted"]),
                        sts.zn[:, 0], sc.counters["stats"],
                        solver=xc.solver_stats(sts), health=health)
        return res, sc.counters["rounds"]

    run.init_carry = init_carry
    run.round_body = round_body
    run.tenant_round = tenant_round   # (carry, iinj, active, k_qos) — serve
    run.cond = cond
    run.pack = pack           # carry tuple <-> SimCarry (checkpoint tests)
    run.unpack = unpack
    run.batch_cap = cap
    run.spike_cap = s_cap
    return run


def run_fap_fixed(*args, **kw):
    res, _ = make_fap_fixed_runner(*args, **kw)()
    return res


def run_fap_vardt(*args, **kw):
    res, _ = make_fap_vardt_runner(*args, **kw)()
    return res
